"""Docs link checker (CI gate).

Two guarantees over `README.md` and `docs/*.md`:

1. every **relative markdown link** resolves to an existing file (anchors
   stripped; external http(s)/mailto links are ignored), and
2. every **code entity the docs name** exists: backticked ``*.py`` paths
   must exist on disk (resolved against the repo root and ``src/repro/``),
   and backticked dotted names rooted in a known module (``ops.x``,
   ``ref.x``, ``repro.a.b.c``) must import/getattr cleanly.

Run from the repo root: ``PYTHONPATH=src python tools/check_links.py``
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
PATH_RE = re.compile(r"^[\w./-]+\.(?:py|md|json|txt|yml)$")
DOTTED_RE = re.compile(r"^(ops|ref|repro(?:\.\w+)+)\.(\w+)$")

MODULE_ALIASES = {
    "ops": "repro.kernels.ops",
    "ref": "repro.kernels.ref",
}


def md_files():
    yield ROOT / "README.md"
    yield from sorted((ROOT / "docs").glob("*.md"))


def check_rel_links(md: pathlib.Path, text: str, errors: list):
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (md.parent / rel).exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")


def resolve_path_token(token: str) -> bool:
    candidates = [ROOT / token, ROOT / "src" / "repro" / token]
    return any(c.exists() for c in candidates)


def resolve_dotted(token: str) -> bool:
    m = DOTTED_RE.match(token)
    mod_name, attr = m.group(1), m.group(2)
    mod_name = MODULE_ALIASES.get(mod_name, mod_name)
    try:
        mod = importlib.import_module(mod_name)
    except ImportError:
        # repro.a.b.c.attr may split as module=repro.a.b, attr-chain=c.attr
        parts = mod_name.rsplit(".", 1)
        try:
            mod = importlib.import_module(parts[0])
            mod = getattr(mod, parts[1])
        except (ImportError, AttributeError):
            return False
    return hasattr(mod, attr)


def check_code_tokens(md: pathlib.Path, text: str, errors: list):
    for token in CODE_RE.findall(text):
        token = token.strip().rstrip("()")
        if PATH_RE.match(token) and "/" in token:
            if not resolve_path_token(token):
                errors.append(
                    f"{md.relative_to(ROOT)}: file not found -> `{token}`"
                )
        elif DOTTED_RE.match(token):
            if not resolve_dotted(token):
                errors.append(
                    f"{md.relative_to(ROOT)}: unresolvable name -> `{token}`"
                )


def main() -> int:
    errors: list = []
    n_files = 0
    for md in md_files():
        text = md.read_text()
        n_files += 1
        check_rel_links(md, text, errors)
        if md.parent.name == "docs":
            check_code_tokens(md, text, errors)
    if errors:
        print(f"link check FAILED ({len(errors)} problem(s)):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"link check OK: {n_files} markdown files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
