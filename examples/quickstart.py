"""Quickstart: packed irregular streams end-to-end in 60 lines.

1. the core API (strided/indirect gather-scatter, the AXI-Pack converters),
2. the bus-packing law they implement,
3. a tiny LM using them (embedding gather + MoE dispatch) for a few steps.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BusConfig, StridedStream, System, stream_cycles
from repro.kernels import ops
from repro.configs import smoke_config
from repro.models import lm
from repro.optim import OptimizerConfig, make_optimizer
from repro.parallel.sharding import make_rules
from repro.train import make_train_step

# --- 1. packed streams ------------------------------------------------------
rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(1024, 256)), jnp.float32)

# strided read: rows 3, 7, 11, ... packed into a dense block (stride burst)
packed = ops.strided_gather(table, base=3, stride=4, count=8)
print("strided_gather:", packed.shape)

# indirect read: memory-resident indices drive the DMA (vlimxei semantics)
idx = jnp.asarray(rng.integers(0, 1024, 16), jnp.int32)
gathered = ops.indirect_gather(table, idx)
print("indirect_gather:", gathered.shape)

# --- 2. why packing matters: the bus model ----------------------------------
cfg = BusConfig()  # 256-bit bus, fp32 elements
s = StridedStream(base=0, elem_bits=32, count=4096, stride=7)
base = stream_cycles(s, System.BASE, cfg).cycles
pack = stream_cycles(s, System.PACK, cfg).cycles
print(f"stride-7 stream of 4096 fp32: BASE {base:.0f} cyc → PACK {pack:.0f} cyc "
      f"({base/pack:.1f}x, paper's peak is 5.4x system-level)")

# --- 3. a tiny MoE LM whose embedding + dispatch are packed streams ----------
arch = smoke_config("olmoe-1b-7b")
rules = make_rules(with_pod=False, batch_axes=None)
params = lm.init_model(arch, jax.random.PRNGKey(0))
opt = make_optimizer(OptimizerConfig(lr=1e-2, warmup_steps=5, total_steps=50))
state = opt.init(params)
step = jax.jit(make_train_step(arch, opt, rules))

toks = jnp.asarray(rng.integers(0, arch.vocab, (4, 33)))
batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
         "mask": jnp.ones((4, 32))}
for i in range(10):
    params, state, metrics = step(params, state, batch, i)
print(f"10 steps on the smoke MoE: loss {float(metrics['loss']):.3f} "
      f"(memorizing one batch, should fall below ln(V)={np.log(arch.vocab):.2f})")
