"""The paper's irregular workloads running on the packed-stream substrate.

Each workload prints: verified-correct result, packed-vs-base traffic
efficiency (the measured counterpart of Fig. 3), and the modeled
BASE/PACK/IDEAL cycles from the bus model + banked-endpoint simulator.

Run: PYTHONPATH=src:. python examples/sparse_ops.py
"""
import jax.numpy as jnp
import numpy as np

from benchmarks import workload_impls as W
from benchmarks.paper_workloads import (
    evaluate, gemv_model, ismt_model, spmv_model, sssp_model, synth_csr,
)
from repro.kernels import ref

rng = np.random.default_rng(0)
n = 128

# ismt — strided tile streams
a = rng.normal(size=(n, n)).astype(np.float32)
out, tr = W.ismt(jnp.asarray(a))
assert np.allclose(np.asarray(out), a.T)
row = evaluate(ismt_model(n))
print(f"ismt   ok | traffic eff base {tr['base_eff']:.2f} → pack {tr['pack_eff']:.2f} "
      f"| modeled speedup {row.speedup_pack:.2f}x")

# gemv — column dataflow strided streams
x = rng.normal(size=(n,)).astype(np.float32)
y, tr = W.gemv_col(jnp.asarray(a), jnp.asarray(x))
assert np.allclose(np.asarray(y), a @ x, rtol=1e-4)
row = evaluate(gemv_model(n, "col"))
print(f"gemv   ok | modeled PACK bus util {row.util_pack:.1%} (paper 87%)")

# spmv / pagerank / sssp — indirect streams over CSR→ELL
indptr, indices, data = synth_csr(n, 24, n_cols=n, seed=1)
vals, cols = ref.csr_to_ell(indptr, indices, data, n)
dense = np.zeros((n, n), np.float32)
for r in range(n):
    dense[r, indices[indptr[r]:indptr[r+1]]] = data[indptr[r]:indptr[r+1]]
y, tr = W.spmv(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
assert np.allclose(np.asarray(y), dense @ x, rtol=1e-4, atol=1e-4)
row = evaluate(spmv_model(indptr, indices))
print(f"spmv   ok | traffic eff base {tr['base_eff']:.2f} → pack {tr['pack_eff']:.2f} "
      f"| modeled speedup {row.speedup_pack:.2f}x")

adj = (np.abs(dense) > 0).astype(np.float32) + np.eye(n, dtype=np.float32)
pvals_dense = adj / adj.sum(0, keepdims=True)
ip, ix, dv = [], [], []
indptr2 = [0]
for r in range(n):
    nz = np.nonzero(pvals_dense[r])[0]
    ix.extend(nz); dv.extend(pvals_dense[r, nz]); indptr2.append(len(ix))
pv, pc = ref.csr_to_ell(np.asarray(indptr2), np.asarray(ix, np.int32),
                        np.asarray(dv, np.float32), n)
ranks, _ = W.pagerank(jnp.asarray(pv), jnp.asarray(pc), n, iters=40)
print(f"prank  ok | sums to {float(jnp.sum(ranks)):.3f}, "
      f"top node {int(jnp.argmax(ranks))}")

mask = vals != 0
wv = np.abs(vals) + mask * 0.1
dist, _ = W.sssp(jnp.asarray(wv), jnp.asarray(cols), jnp.asarray(mask),
                 src=0, n=n, iters=12)
reach = int(np.isfinite(np.asarray(dist)[np.asarray(dist) < 1e29].sum()))
row = evaluate(sssp_model(indptr, indices))
print(f"sssp   ok | {int((np.asarray(dist) < 1e29).sum())}/{n} reachable "
      f"| modeled speedup {row.speedup_pack:.2f}x")
