"""End-to-end training driver: MoE LM + packed dispatch + fault tolerance.

Trains a scaled-down olmoe-family model on a synthetic Markov corpus with
checkpointing, auto-resume and the straggler watchdog — the same controller
and step factory the production launcher uses.  ``--preset 100m`` instantiates
a ~100M-parameter model (sized for real hardware; the default ~5M preset
keeps this CPU-only container to a few minutes for a few hundred steps).

Run: PYTHONPATH=src python examples/train_moe.py --steps 200
"""
import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import TokenDataset, synthetic_corpus
from repro.models import lm
from repro.optim import OptimizerConfig, make_optimizer
from repro.parallel.sharding import make_rules
from repro.runtime import FaultToleranceConfig, TrainController
from repro.train import make_train_step

PRESETS = {
    # ~5M params: CPU-friendly demo
    "5m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_ff=128,
               vocab=2048, n_experts=8, top_k=2),
    # ~100M params: a few hundred steps on one accelerator host
    "100m": dict(n_layers=8, d_model=768, n_heads=12, n_kv_heads=12, d_ff=512,
                 vocab=16384, n_experts=16, top_k=4),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="5m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--workdir", default="/tmp/repro_train_moe")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b"), **PRESETS[args.preset],
        dtype="float32", param_dtype="float32", remat=False,
        shard_kv_heads=False,
    )
    rules = make_rules(with_pod=False, batch_axes=None)

    corpus = os.path.join(args.workdir, "corpus")
    if not os.path.exists(os.path.join(corpus, "meta.json")):
        synthetic_corpus(corpus, n_tokens=300_000, vocab=cfg.vocab)
    ds = TokenDataset(corpus, args.seq, args.batch)

    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n/1e6:.1f}M params, {cfg.n_experts} experts top-{cfg.top_k}")

    opt = make_optimizer(OptimizerConfig(
        lr=args.lr, warmup_steps=20, total_steps=args.steps))
    opt_state = opt.init(params)
    jitted = jax.jit(make_train_step(cfg, opt, rules), donate_argnums=(0, 1))

    def step_fn(state, batch, step):
        p, o, m = jitted(state["params"], state["opt"], batch, step)
        return {"params": p, "opt": o}, m

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in ds.batch(step).items()}

    ctl = TrainController(
        step_fn, make_batch,
        FaultToleranceConfig(ckpt_dir=os.path.join(args.workdir, "ckpt"),
                             ckpt_every=50),
    )
    # auto-resumes if a checkpoint exists (kill it mid-run and rerun to see)
    ctl.run({"params": params, "opt": opt_state}, args.steps, log_every=20)
    losses = [h["loss"] for h in ctl.history]
    if losses:
        print(f"loss: first-10 {np.mean(losses[:10]):.3f} → "
              f"last-10 {np.mean(losses[-10:]):.3f}")
        print(f"stragglers observed: {ctl.watchdog.stragglers}")


if __name__ == "__main__":
    main()
