"""Serving example: batched generation + the paged-KV indirect stream kernel.

Part 1 serves a small dense model through the dense baseline loop (prefill
+ greedy decode with the sequence-sharded contiguous cache — what the
dry-run's decode cells lower).

Part 2 demonstrates the paged cache directly: scattered physical pages, a
page table as the AXI-Pack indirect stream descriptor, and the Pallas
``paged_decode_attention`` kernel consuming it (validated vs the oracle),
including the int8-packed variant (narrower elements → half the HBM
traffic, the paper's §III-E element-size argument).

Part 3 runs the continuous-batching scheduler: requests of different lengths
enter a tight page pool, prefill chunks interleave with batched decode
steps, one request is evicted and replayed bit-for-bit, and every decode
step's PACK-vs-BASE traffic is accounted through the same indirect-stream
descriptors the kernel consumes.

Part 4 re-runs the scheduler with ``kv_dtype='int8'``: the pools hold int8
codes plus fp32 scale sidebands, K/V rows are quantized on write, both
attention kernels dequantize page-by-page, and the traffic accounting
shows the quadrupled packing factor (pool bytes ÷4 vs fp32).

Part 5 serves a *recurrent* model (RWKV6) through the very same scheduler:
fixed-size state slots instead of growing page chains, strided-burst
accounting instead of indirect, same admission/eviction/replay machinery —
the family protocol in action.

Run: PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.kernels import ops, ref
from repro.launch.serve import dense_generate
from repro.models import lm
from repro.parallel.sharding import make_rules
from repro.serve import (
    PagedKVCache, PagedLM, RecurrentLM, Request, Scheduler,
    recurrent_reference_generate, static_batch_generate,
)

rng = np.random.default_rng(0)

# --- Part 1: dense baseline loop ---------------------------------------------
cfg = smoke_config("yi-6b")
rules = make_rules(with_pod=False, batch_axes=None)
params = lm.init_model(cfg, jax.random.PRNGKey(0))
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 12)), jnp.int32)
out = dense_generate(cfg, params, rules, prompts, n_new=16, max_len=64)
print("dense baseline generated:", out.shape, "first row:", out[0][:8].tolist())

# --- Part 2: paged KV + indirect-stream kernel -------------------------------
B, H, KVH, D, page, npages = 4, 8, 2, 32, 16, 4
pool = 32
cache = PagedKVCache.create(smoke_config("yi-6b"), batch=B, max_len=page * npages,
                            page=page)
print(f"paged pool: {pool} pages × {page} tokens (free: {len(cache.free)})")

kp = jnp.asarray(rng.normal(size=(pool, page, KVH, D)), jnp.float32)
vp = jnp.asarray(rng.normal(size=(pool, page, KVH, D)), jnp.float32)
table = jnp.asarray(rng.permutation(pool)[: B * npages].reshape(B, npages),
                    jnp.int32)
lengths = jnp.asarray(rng.integers(1, page * npages, B), jnp.int32)
q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)

o_kernel = ops.paged_decode_attention(q, kp, vp, table, lengths)        # Pallas
o_oracle = ops.paged_decode_attention(q, kp, vp, table, lengths, impl="ref")
err = float(jnp.abs(o_kernel - o_oracle).max())
print(f"paged_decode kernel vs oracle: max err {err:.2e}")

# int8-packed pages: half the bytes per KV element on the stream
kq, ks = ref.int8_quantize(kp, axis=-1)
vq, vs = ref.int8_quantize(vp, axis=-1)
o_int8 = ops.paged_decode_attention(q, kq, vq, table, lengths,
                                    k_scale=ks[..., 0], v_scale=vs[..., 0])
q_err = float(jnp.abs(o_int8 - o_oracle).max())
bytes_bf16 = kp.size * 2 * 2
bytes_int8 = kp.size * 2 * 1 + ks.size * 4 * 2
print(f"int8-packed cache: err {q_err:.3f}, stream bytes "
      f"{bytes_bf16/2**20:.1f} MiB → {bytes_int8/2**20:.1f} MiB "
      f"({bytes_bf16/bytes_int8:.2f}x reduction)")

# --- Part 3: continuous-batching scheduler -----------------------------------
cfg3 = smoke_config("yi-6b")
model = PagedLM(cfg3, jax.random.PRNGKey(0), impl="ref")
prompts = [rng.integers(0, cfg3.vocab, n).astype(np.int32) for n in (8, 7, 12)]
max_new = 8

# Static reference: every request resident from step 0 in an ample pool.
want = static_batch_generate(
    model, PagedKVCache.create(cfg3, batch=3, max_len=32, page=4),
    prompts, max_new, chunk=4,
)

# Scheduled: a 9-page pool can't hold all three sequences at their peak, so
# admission staggers and the youngest resident gets evicted and replayed.
cache3 = PagedKVCache.create(cfg3, batch=3, max_len=32, page=4, pool_pages=9)
sched = Scheduler(model, cache3, chunk=4)
for i, p in enumerate(prompts):
    sched.submit(Request(
        rid=i, prompt=p, max_new=max_new,
        on_token=lambda r, t: print(f"  stream rid={r.rid} token={t}"),
    ))
out = sched.run()
st = sched.stats
match = all(out[i] == want[i] for i in out)
print(f"scheduler: {st.tokens} tokens in {st.decode_steps} decode steps, "
      f"{st.n_evictions} eviction(s); matches static batch: {match}")
print(f"per-step bus traffic: PACK {st.pack_bytes/2**10:.0f} KiB "
      f"({st.pack_efficiency:.0%} useful) vs BASE {st.base_bytes/2**10:.0f} "
      f"KiB ({st.base_efficiency:.0%} useful)")
assert match, "scheduled decode diverged from the static batch"

# --- Part 4: int8 page pools under the scheduler -----------------------------
model8 = PagedLM(cfg3, jax.random.PRNGKey(0), impl="ref", kv_dtype="int8")
cache8 = PagedKVCache.create(cfg3, batch=3, max_len=32, page=4, pool_pages=9,
                             kv_dtype="int8")
sched8 = Scheduler(model8, cache8, chunk=4)
for i, p in enumerate(prompts):
    sched8.submit(Request(rid=i, prompt=p, max_new=max_new))
out8 = sched8.run()
st8 = sched8.stats
cache_fp = PagedKVCache.create(cfg3, batch=3, max_len=32, page=4, pool_pages=9)
print(f"int8 scheduler: {st8.tokens} tokens, pool "
      f"{cache_fp.pool_bytes/2**10:.0f} KiB fp32 → "
      f"{sched8.cache.pool_bytes/2**10:.0f} KiB int8 "
      f"({cache_fp.pool_bytes / sched8.cache.pool_bytes:.2f}x smaller)")
print(f"int8 PACK {st8.pack_bytes/2**10:.0f} KiB vs fp32 PACK "
      f"{st.pack_bytes/2**10:.0f} KiB on the same workload; BASE eff "
      f"{st8.base_efficiency:.0%} (narrow elements in full-width slots) vs "
      f"PACK eff {st8.pack_efficiency:.0%}")
# Greedy decode is robust to the quantization noise on this workload: the
# token streams match the full-precision run exactly.
print("int8 tokens match fp32 run:", out8 == out)
assert out8 == out, "int8 greedy decode diverged from the fp32 run"

# --- Part 5: a recurrent family through the same scheduler -------------------
cfgr = smoke_config("rwkv6-3b")
rlm = RecurrentLM(cfgr, jax.random.PRNGKey(0), impl="ref")
rprompts = [rng.integers(0, cfgr.vocab, n).astype(np.int32) for n in (8, 7, 12)]
# Direct sequential forward at the same batch shape — the ground truth.
want_r = recurrent_reference_generate(rlm, rlm.init_pool(3), rprompts, max_new)

# Same scheduler class, zero paged-KV anything: one fixed-size state slot
# per resident, strided-burst accounting instead of page-table indirect.
sched_r = Scheduler(rlm.bind(rlm.init_pool(3)), chunk=4)
for i, p in enumerate(rprompts):
    sched_r.submit(Request(rid=i, prompt=p, max_new=max_new))
out_r = sched_r.run()
st_r = sched_r.stats
match_r = all(out_r[i] == want_r[i] for i in out_r)
print(f"recurrent scheduler: {st_r.tokens} tokens in {st_r.decode_steps} "
      f"decode steps; matches direct forward: {match_r}")
print(f"strided PACK {st_r.pack_bytes/2**10:.0f} KiB "
      f"({st_r.pack_efficiency:.0%} useful — dense fixed-stride state, no "
      f"index tax) vs BASE {st_r.base_bytes/2**10:.0f} KiB "
      f"({st_r.base_efficiency:.0%})")
assert match_r, "recurrent scheduled decode diverged from direct forward"
