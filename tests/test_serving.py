"""Serving-path tests: chunked prefill equivalence, dense baseline
generation, paged cache bookkeeping, w8a16 end-to-end generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCH_NAMES, smoke_config
from repro.launch.serve import dense_generate
from repro.models import lm
from repro.parallel.sharding import make_rules
from repro.serve import PagedKVCache

RULES = make_rules(with_pod=False, batch_axes=None)


@pytest.mark.parametrize("name", ["yi-6b", "gemma3-27b", "rwkv6-3b",
                                  "hymba-1.5b", "olmoe-1b-7b"])
def test_chunked_prefill_equals_monolithic(name):
    cfg = smoke_config(name)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)  # no drops
    rng = np.random.default_rng(0)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)))
    c1 = lm.init_cache(cfg, 2, 32)
    l1, c1 = lm.prefill(params, {"tokens": toks}, c1, cfg, RULES)
    c2 = lm.init_cache(cfg, 2, 32)
    l2, c2 = lm.prefill_chunked(params, {"tokens": toks}, c2, cfg, RULES, chunk=8)
    assert float(jnp.abs(l1 - l2).max()) < 2e-2
    for a, b in zip(jax.tree_util.tree_leaves(c1), jax.tree_util.tree_leaves(c2)):
        assert float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) < 2e-2


def test_dense_generate_greedy_deterministic():
    cfg = smoke_config("yi-6b")
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (3, 8)), jnp.int32)
    o1 = dense_generate(cfg, params, RULES, prompts, n_new=8, max_len=32)
    o2 = dense_generate(cfg, params, RULES, prompts, n_new=8, max_len=32)
    np.testing.assert_array_equal(o1, o2)
    assert o1.shape == (3, 8)
    assert o1.max() < cfg.vocab  # TP-padding classes never sampled


def test_dense_generate_matches_decode_loop():
    """dense_generate output == hand-rolled prefill+decode greedy loop."""
    cfg = smoke_config("qwen2.5-14b")
    params = lm.init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    out = dense_generate(cfg, params, RULES, prompts, n_new=6, max_len=24)

    cache = lm.init_cache(cfg, 2, 24)
    logits, cache = lm.prefill(params, {"tokens": prompts}, cache, cfg, RULES)
    tok = jnp.argmax(logits[:, 0, : cfg.vocab], axis=-1).astype(jnp.int32)
    ref = []
    for i in range(6):
        ref.append(np.asarray(tok))
        logits, cache = lm.decode_step(params, tok[:, None], cache, 6 + i, cfg, RULES)
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(out, np.stack(ref, axis=1))


def test_paged_cache_allocation_lifecycle():
    cfg = smoke_config("yi-6b")
    cache = PagedKVCache.create(cfg, batch=4, max_len=64, page=16)
    assert len(cache.free) == 16
    cache = cache.allocate(seq=0, n_pages=3)
    assert len(cache.free) == 13
    table = np.asarray(cache.page_table)
    assert len(set(table[0, :3].tolist())) == 3  # distinct physical pages
    lengths = np.asarray(cache.lengths).copy()
    lengths[0] = 40  # 3 pages in use
    cache = dataclasses.replace(cache, lengths=jnp.asarray(lengths))
    cache = cache.release(seq=0)
    assert len(cache.free) == 16
    assert int(np.asarray(cache.lengths)[0]) == 0


def test_paged_cache_release_does_not_corrupt_old_copies():
    """Regression: ``release`` (and ``allocate``) must copy host bookkeeping
    before writing.  Previously ``release`` mutated ``self.mapped`` (and the
    shared ``free`` list) in place, silently corrupting every older cache
    object that the functional ``dataclasses.replace`` API implies is
    immutable."""
    cfg = smoke_config("yi-6b")
    cache0 = PagedKVCache.create(cfg, batch=2, max_len=32, page=8)
    cache1 = cache0.allocate(seq=0, n_pages=3)
    free_before = list(cache1.free)
    mapped_before = cache1.mapped.copy()
    table_before = np.asarray(cache1.page_table).copy()

    cache2 = cache1.release(seq=0)
    # The new cache sees the release...
    assert cache2.mapped[0] == 0
    assert len(cache2.free) == len(free_before) + 3
    # ...but the older caches are untouched.
    np.testing.assert_array_equal(cache1.mapped, mapped_before)
    assert cache1.free == free_before
    np.testing.assert_array_equal(np.asarray(cache1.page_table), table_before)
    assert cache0.mapped[0] == 0 and len(cache0.free) == 8

    # allocate() must not leak page ids into older copies either.
    cache3 = cache2.allocate(seq=1, n_pages=2)
    assert len(cache2.free) == len(cache3.free) + 2
    assert cache2.mapped[1] == 0 and cache3.mapped[1] == 2


def test_w8a16_generation_consistent():
    """Quantized-MLP generation produces valid tokens and mostly agrees with
    full precision on a short greedy rollout."""
    cfg = smoke_config("qwen1.5-32b")
    params = lm.init_model(cfg, jax.random.PRNGKey(2))
    qparams = lm.quantize_mlp_weights(params, cfg)
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    o_full = dense_generate(cfg, params, RULES, prompts, 4, max_len=24)
    o_q = dense_generate(cfg, qparams, RULES, prompts, 4, max_len=24)
    assert o_q.shape == o_full.shape
    assert o_q.max() < cfg.vocab
    # random-init logits are near-ties, so just require the first step agrees
    # for at least one sequence (quantization err ≲0.04 per logit)
    assert (o_q[:, 0] == o_full[:, 0]).any()
