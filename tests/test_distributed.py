"""Distributed-path tests on 8 fake CPU devices (subprocess: the main test
process must keep its 1-device view).

Covers the shard_map paths that only activate under a mesh: the EP MoE
dispatcher, the shard-local embedding gather/scatter, int8 error-feedback
gradient all-reduce, and w8a16 serving weights.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import lm
from repro.parallel.sharding import make_rules

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Several subprocess bodies drive the explicit-mesh sharding APIs
# (jax.sharding.AxisType / jax.set_mesh) introduced in jax 0.5+; on older
# pinned jaxlib hosts they cannot run at all.
requires_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs jax>=0.5 explicit-mesh APIs (jax.sharding.AxisType)",
)


def _run(body: str) -> str:
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        f"import sys; sys.path.insert(0, {os.path.join(ROOT, 'src')!r})\n"
        + body
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=420, cwd=ROOT,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@requires_axis_type
def test_ep_moe_matches_fallback():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import smoke_config
from repro.models import mlp as M
from repro.models.common import init_params
from repro.models.mlp import moe_defs
from repro.parallel.sharding import make_rules
from jax.sharding import PartitionSpec as P, NamedSharding

mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = dataclasses.replace(smoke_config("olmoe-1b-7b"), n_experts=8, top_k=2,
                          capacity_factor=8.0)
rules_ep = make_rules(with_pod=False, batch_axes=("data",), mesh=mesh)
rules_ref = make_rules(with_pod=False, batch_axes=None)
params = init_params(moe_defs(cfg), jax.random.PRNGKey(0))
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, cfg.d_model)), jnp.float32)
ref_out, ref_aux = M.moe_fwd(params, x, cfg, rules_ref)
with jax.set_mesh(mesh):
    ep_out, ep_aux = jax.jit(lambda p, xx: M.moe_fwd(p, xx, cfg, rules_ep))(
        params, jax.device_put(x, NamedSharding(mesh, P("data"))))
err = float(jnp.abs(ep_out - ref_out).max())
assert err < 1e-4, err
def loss_ep(p):
    o, a = M.moe_fwd(p, x, cfg, rules_ep); return jnp.sum(o**2) + a
def loss_ref(p):
    o, a = M.moe_fwd(p, x, cfg, rules_ref); return jnp.sum(o**2) + a
with jax.set_mesh(mesh):
    g1 = jax.jit(jax.grad(loss_ep))(params)
g2 = jax.grad(loss_ref)(params)
gerr = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
    lambda a, b: float(jnp.abs(a - b).max()), g1, g2)))
assert gerr < 1e-4, gerr
print("EP_OK", err, gerr)
""")
    assert "EP_OK" in out


@requires_axis_type
def test_sharded_embedding_gather_matches_take():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.models.lm import _embed_lookup
from repro.parallel.sharding import make_rules
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rules = make_rules(with_pod=False, batch_axes=("data",), mesh=mesh)
rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
ids = jnp.asarray(rng.integers(0, 128, (4, 8)), jnp.int32)
with jax.set_mesh(mesh):
    got = jax.jit(lambda t, i: _embed_lookup(t, i, rules, jnp.float32))(table, ids)
np.testing.assert_allclose(np.asarray(got), np.asarray(table)[np.asarray(ids)],
                           rtol=1e-5)
# gradient wrt table: scatter-add semantics
def loss(t):
    return jnp.sum(_embed_lookup(t, ids, rules, jnp.float32) ** 2)
with jax.set_mesh(mesh):
    g = jax.jit(jax.grad(loss))(table)
expect = np.zeros_like(np.asarray(table))
np.add.at(expect, np.asarray(ids).ravel(),
          2 * np.asarray(table)[np.asarray(ids)].reshape(-1, 64))
np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-4, atol=1e-4)
print("EMB_OK")
""")
    assert "EMB_OK" in out


@requires_axis_type
def test_int8_psum_error_feedback():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compression
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
N = 1000
xs = jnp.asarray(rng.normal(size=(8, N)).astype(np.float32))
err = jnp.zeros((8, N), jnp.float32)
def f(x, e):
    o, ne = compression.int8_psum(x[0], "data", e[0])
    return o[None], ne[None]
fm = jax.shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=(P("data"), P("data")), check_vma=False)
true = np.asarray(xs).sum(0)
acc = np.zeros(N)
for it in range(20):
    out, err = jax.jit(fm)(xs, err)
    acc += np.asarray(out)[0]
r0 = np.abs(np.asarray(out)[0] - true).max() / np.abs(true).max()
r20 = np.abs(acc / 20 - true).max() / np.abs(true).max()
assert r0 < 0.05 and r20 < r0, (r0, r20)   # EF mean converges
print("COMP_OK", r0, r20)
""")
    assert "COMP_OK" in out


def test_w8a16_quantized_forward_close():
    cfg = smoke_config("qwen1.5-32b")
    rules = make_rules(with_pod=False, batch_axes=None)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    qparams = lm.quantize_mlp_weights(params, cfg)
    # structure: MLP leaves became {'q','scale'} with int8 payload
    leaf = qparams["layers"]["mlp"]["w_up"]
    assert leaf["q"].dtype == jnp.int8
    assert leaf["scale"].shape[-2] == 1
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)))
    cache = lm.init_cache(cfg, 2, 16)
    l1, _ = lm.prefill(params, {"tokens": tokens}, cache, cfg, rules)
    cache = lm.init_cache(cfg, 2, 16)
    l2, _ = lm.prefill(qparams, {"tokens": tokens}, cache, cfg, rules)
    assert float(jnp.abs(l1 - l2).max()) < 0.1


@requires_axis_type
def test_compressed_train_step_runs():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import smoke_config
from repro.models import lm
from repro.optim import OptimizerConfig, make_optimizer
from repro.train import make_compressed_train_step
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
cfg = dataclasses.replace(smoke_config("yi-6b"), shard_kv_heads=False)
opt = make_optimizer(OptimizerConfig(lr=1e-2, warmup_steps=2, total_steps=50))
params = lm.init_model(cfg, jax.random.PRNGKey(0))
state = opt.init(params)
step = make_compressed_train_step(cfg, opt, mesh, dp_axes=("data",))
err_fb = step.init_err_fb(params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32))),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32))),
         "mask": jnp.ones((8, 32))}
with jax.set_mesh(mesh):
    jstep = jax.jit(step)
    losses = []
    for i in range(6):
        params, state, err_fb, metrics = jstep(params, state, batch, i, err_fb)
        losses.append(float(metrics["loss"]))
assert losses[-1] < losses[0], losses   # learns through int8 gradients
print("CSTEP_OK", losses[0], losses[-1])
""")
    assert "CSTEP_OK" in out


def test_elastic_rescale_across_mesh_sizes():
    """Checkpoint written under an 8-device mesh restores onto a 4-device
    mesh (simulated node loss) with identical values — the elastic path."""
    out = _run("""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import CheckpointManager
from repro.runtime import reshard_state

d8 = jax.devices()[:8]
d4 = jax.devices()[:4]
mesh8 = jax.sharding.Mesh(np.array(d8).reshape(4, 2), ("data", "model"))
mesh4 = jax.sharding.Mesh(np.array(d4).reshape(2, 2), ("data", "model"))

tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((16,))}
sh8 = {"w": NamedSharding(mesh8, P("data", "model")),
       "b": NamedSharding(mesh8, P("data"))}
state8 = jax.tree_util.tree_map(jax.device_put, tree, sh8)

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, state8)
    sh4 = {"w": NamedSharding(mesh4, P("data", "model")),
           "b": NamedSharding(mesh4, P("data"))}
    state4 = mgr.restore(1, sh4)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(state4[k]), np.asarray(tree[k]))
        assert state4[k].sharding.mesh.shape["data"] == 2
    # and the in-memory reshard path (no disk)
    state4b = reshard_state(state8, sh4)
    np.testing.assert_array_equal(np.asarray(state4b["w"]), np.asarray(tree["w"]))
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
