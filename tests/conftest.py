import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..'))

# The property tests import `hypothesis`; it is an optional dev dependency.
# When absent, install the deterministic stub (tests/_hypothesis_stub.py) under
# the `hypothesis` name before collection so `pytest -x -q` runs everywhere.
if importlib.util.find_spec("hypothesis") is None:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
