"""Core library tests: stream semantics, bus model laws, bank simulator."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BankConfig,
    BusConfig,
    ContiguousStream,
    IndirectStream,
    StridedStream,
    System,
    beats_for,
    indirect_traffic,
    indirect_utilization_ceiling,
    stream_cycles,
    strided_traffic,
)
from repro.core.banksim import (
    crossbar_area_kge,
    indirect_utilization,
    simulate_stream,
    strided_utilization,
)


# ---------------------------------------------------------------------------
# Stream descriptors
# ---------------------------------------------------------------------------


def test_stride_one_degrades_to_base():
    s = StridedStream(base=0, elem_bits=32, count=16, stride=1)
    assert s.kind.value == "base"


def test_indirect_offsets():
    idx = np.array([3, 1, 4, 1, 5])
    s = IndirectStream(base=10, elem_bits=32, count=5, indices=idx)
    np.testing.assert_array_equal(s.element_offsets(), 10 + idx)


# ---------------------------------------------------------------------------
# Bus model: the paper's analytical laws
# ---------------------------------------------------------------------------


def test_base_strided_narrow_beats():
    """BASE strided: one narrow beat per element at the calibrated issue cost
    (base_strided_cpe, calibrated on Fig. 3a's ismt); bus utilization is
    bounded by e/W = 12.5 % for fp32 on 256 bits."""
    cfg = BusConfig()
    s = StridedStream(base=0, elem_bits=32, count=256, stride=7)
    cost = stream_cycles(s, System.BASE, cfg)
    assert cost.cycles == 256 * cfg.base_strided_cpe
    assert cost.data_beats == 256
    useful_fraction = (256 * 32) / (cost.data_beats * cfg.bus_bits)
    assert useful_fraction == pytest.approx(0.125)  # e/W beat efficiency


def test_pack_strided_is_fully_packed():
    cfg = BusConfig()
    s = StridedStream(base=0, elem_bits=32, count=256, stride=7)
    cost = stream_cycles(s, System.PACK, cfg)
    assert cost.cycles == 32           # 256 * 32b / 256b
    assert (256 * 32) / (cost.cycles * cfg.bus_bits) == pytest.approx(1.0)


@pytest.mark.parametrize("i_bits,expect", [(32, 0.5), (16, 2 / 3), (8, 0.8)])
def test_r_over_r_plus_one_law(i_bits, expect):
    """§III-E: ideal indirect utilization = r/(r+1)."""
    assert indirect_utilization_ceiling(32, i_bits) == pytest.approx(expect)
    # And the cycle model realizes exactly that ceiling with no conflicts:
    cfg = BusConfig()
    n = 1024
    idx = np.arange(n)
    s = IndirectStream(base=0, elem_bits=32, count=n, indices=idx, index_bits=i_bits)
    cost = stream_cycles(s, System.PACK, cfg)
    assert cost.data_beats / cost.cycles == pytest.approx(expect, rel=1e-3)


def test_pack_never_slower_than_base():
    """The paper's request-bundling guarantee: PACK ≤ BASE for any stream."""
    cfg = BusConfig()
    rng = np.random.default_rng(0)
    for count in [1, 2, 7, 64, 999]:
        s1 = StridedStream(base=0, elem_bits=32, count=count, stride=5)
        assert (
            stream_cycles(s1, System.PACK, cfg).cycles
            <= stream_cycles(s1, System.BASE, cfg).cycles
        )
        idx = rng.integers(0, 4096, count)
        s2 = IndirectStream(base=0, elem_bits=32, count=count, indices=idx)
        assert (
            stream_cycles(s2, System.PACK, cfg).cycles
            <= stream_cycles(s2, System.BASE, cfg).cycles
        )


@settings(max_examples=30, deadline=None)
@given(
    count=st.integers(1, 2048),
    elem_bits=st.sampled_from([8, 16, 32, 64]),
    stride=st.integers(2, 64),
)
def test_pack_speedup_bounded_by_packing_factor(count, elem_bits, stride):
    """Property: PACK speedup over BASE ≤ cpe × bus/elem ratio (Fig. 3d limit)."""
    cfg = BusConfig()
    s = StridedStream(base=0, elem_bits=elem_bits, count=count, stride=stride)
    b = stream_cycles(s, System.BASE, cfg).cycles
    p = stream_cycles(s, System.PACK, cfg).cycles
    assert b / p <= cfg.base_strided_cpe * cfg.bus_bits / elem_bits + 1e-9


# ---------------------------------------------------------------------------
# Traffic accounting
# ---------------------------------------------------------------------------


def test_traffic_accounting():
    t = strided_traffic(count=256, elem_bytes=4, stride=8, granule_bytes=32)
    assert t.useful_bytes == 1024
    assert t.base_bytes == 256 * 32           # one granule per element
    assert t.pack_bytes == 1024               # dense
    ti = indirect_traffic(count=256, elem_bytes=4, index_bytes=4)
    assert ti.index_bus_bytes_base == 1024
    assert ti.index_bus_bytes_pack == 0       # endpoint-side indirection


# ---------------------------------------------------------------------------
# Bank simulator: Fig. 5 sensitivity laws
# ---------------------------------------------------------------------------


def test_prime_banks_beat_pow2_on_strided():
    """Fig. 5b: prime bank counts avoid stride aliasing."""
    util = {}
    for banks in (16, 17):
        cfg = BankConfig(n_ports=8, n_banks=banks, queue_depth=32)
        util[banks] = np.mean([strided_utilization(s, cfg) for s in range(64)])
    assert util[17] > util[16]
    assert util[17] > 0.9  # paper: 17 banks ≈ 95 % of ideal


def test_indirect_monotonic_in_banks():
    """Fig. 5a: utilization rises monotonically with bank count."""
    us = []
    for banks in (8, 16, 32):
        cfg = BankConfig(n_ports=8, n_banks=banks, queue_depth=32)
        us.append(indirect_utilization(cfg, 32, 32, burst_len=256))
    assert us[0] < us[1] < us[2]
    assert us[-1] <= 0.5 + 1e-9  # r/(r+1) ceiling for 32b/32b


def test_indirect_ratio_effect():
    """Fig. 5a: smaller indices (larger r) raise achievable utilization."""
    cfg = BankConfig(n_ports=8, n_banks=17, queue_depth=32)
    u32 = indirect_utilization(cfg, 32, 32, burst_len=256)
    u16 = indirect_utilization(cfg, 32, 16, burst_len=256)
    u8 = indirect_utilization(cfg, 32, 8, burst_len=256)
    assert u32 < u16 < u8


def test_larger_elements_reduce_strided_conflicts():
    """Fig. 5b: with 64-bit elements conflicts drop vs 32-bit."""
    cfg = BankConfig(n_ports=8, n_banks=16, queue_depth=32)
    u32 = np.mean([strided_utilization(s, cfg, elem_bits=32) for s in range(32)])
    u64 = np.mean([strided_utilization(s, cfg, elem_bits=64) for s in range(32)])
    assert u64 > u32


def test_ideal_memory_is_conflict_free():
    cfg = BankConfig(n_ports=8, n_banks=17, ideal=True)
    s = StridedStream(base=0, elem_bits=32, count=256, stride=8)
    r = simulate_stream(s, cfg)
    assert r.utilization == 1.0 and r.stall_cycles == 0


def test_crossbar_area_model():
    """Fig. 5c: prime counts pay a modulo/divide overhead that shrinks with m."""
    a16, a17 = crossbar_area_kge(8, 16), crossbar_area_kge(8, 17)
    a32, a31 = crossbar_area_kge(8, 32), crossbar_area_kge(8, 31)
    assert a17 > a16                      # prime overhead exists
    rel17 = (a17 - a16) / a16
    rel31 = (a31 - crossbar_area_kge(8, 30)) / crossbar_area_kge(8, 30)
    assert rel31 < rel17                  # and decreases with bank count
    assert a32 > a16                      # datapath grows with banks
