"""Paged prefill attention kernel vs the dense-einsum oracle — specials.

The kernel streams each row's context pages through the scalar-prefetch
indirect path with an online softmax (interpret mode on this CPU host —
identical kernel code compiles on TPU); the oracle gathers the bounded
context densely and runs masked softmax with GQA repeats.  The
GQA × dtype × length cross-product lives in test_oracle_sweep.py; this
module keeps the specials that don't fit a sweep — padding-row NaN
guards, page-boundary straddles, the no-DMA clamp for unmapped tail pages
(fp32 and int8 scale pages), and bf16 accumulation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


def _pool(rng, pool, page, kvh, d):
    k = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    return k, v


def _case(rng, r, c, h, kvh, d, pool, page, ctx):
    kp, vp = _pool(rng, pool, page, kvh, d)
    q = jnp.asarray(rng.normal(size=(r, c, h, d)), jnp.float32)
    rows = jnp.asarray(
        rng.permutation(pool)[: r * ctx].reshape(r, ctx), jnp.int32
    )
    return q, kp, vp, rows


def _both(q, kp, vp, rows, starts, counts):
    want = ops.paged_prefill_attention(
        q, kp, vp, rows, starts, counts, impl="ref"
    )
    got = ops.paged_prefill_attention(
        q, kp, vp, rows, starts, counts, impl="pallas"
    )
    return np.asarray(got), np.asarray(want)


def test_matches_ref_ragged_ctx_and_padding_rows():
    """Per-row context lengths differ by pages; counts==0 padding rows give
    zero output under both implementations (no NaNs) — including a
    *degenerate start* (counts==0 with starts>0), whose context bound is
    forced to zero rather than attending stale pool data."""
    rng = np.random.default_rng(1)
    q, kp, vp, rows = _case(rng, r=5, c=4, h=4, kvh=2, d=16,
                            pool=28, page=4, ctx=5)
    starts = jnp.asarray([0, 12, 4, 0, 9], jnp.int32)
    counts = jnp.asarray([4, 4, 2, 0, 0], jnp.int32)  # ctx pages: 1,4,2,0,0
    got, want = _both(q, kp, vp, rows, starts, counts)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert np.isfinite(got).all() and np.isfinite(want).all()
    assert np.abs(got[3]).max() == 0.0             # padding row → zeros
    assert np.abs(want[3]).max() == 0.0
    assert np.abs(got[4]).max() == 0.0             # degenerate start → zeros
    assert np.abs(want[4]).max() == 0.0


def test_matches_ref_chunk_straddles_page_boundary():
    """A chunk whose tokens span two pages (start mid-page, count past the
    boundary) accumulates across the straddled pages correctly."""
    rng = np.random.default_rng(2)
    q, kp, vp, rows = _case(rng, r=2, c=6, h=4, kvh=2, d=16,
                            pool=12, page=4, ctx=3)
    starts = jnp.asarray([2, 7], jnp.int32)        # both straddle a boundary
    counts = jnp.asarray([6, 5], jnp.int32)
    got, want = _both(q, kp, vp, rows, starts, counts)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matches_ref_exact_page_multiple_boundary():
    """start+count landing exactly on a page boundary (the off-by-one spot):
    the last context page is exactly full and no further page is walked."""
    rng = np.random.default_rng(3)
    q, kp, vp, rows = _case(rng, r=3, c=4, h=4, kvh=2, d=16,
                            pool=16, page=4, ctx=4)
    starts = jnp.asarray([0, 4, 12], jnp.int32)
    counts = jnp.asarray([4, 4, 4], jnp.int32)     # ends at 4, 8, 16 exactly
    got, want = _both(q, kp, vp, rows, starts, counts)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_unmapped_tail_pages_issue_no_dmas():
    """Table entries past a row's last context page may be garbage: the index
    map clamps the walk to the last real page, so a poison page (NaN-filled)
    referenced only by tail entries is never fetched and cannot contaminate
    the output."""
    rng = np.random.default_rng(4)
    pool, page, kvh, d, h, c, ctx = 10, 4, 2, 16, 4, 4, 4
    kp, vp = _pool(rng, pool, page, kvh, d)
    poison = pool - 1
    kp = kp.at[poison].set(jnp.nan)
    vp = vp.at[poison].set(jnp.nan)
    q = jnp.asarray(rng.normal(size=(2, c, h, d)), jnp.float32)
    clean = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    starts = jnp.asarray([0, 2], jnp.int32)
    counts = jnp.asarray([4, 4], jnp.int32)        # ctx pages used: 1, 2
    # Reference on the clean table; kernel with tails pointing at the poison.
    want = ops.paged_prefill_attention(
        q, kp, vp, clean, starts, counts, impl="ref"
    )
    dirty = clean.at[0, 1:].set(poison).at[1, 2:].set(poison)
    got = ops.paged_prefill_attention(
        q, kp, vp, dirty, starts, counts, impl="pallas"
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
    assert np.isfinite(np.asarray(got)).all()


def test_int8_unmapped_tail_pages_issue_no_dmas():
    """The poison-page guarantee holds for the scale pages too: tail table
    entries pointing at a NaN-scale page are never fetched."""
    from repro.kernels import ref

    rng = np.random.default_rng(7)
    pool, page, kvh, d, h, c = 10, 4, 2, 16, 4, 4
    kp, vp = _pool(rng, pool, page, kvh, d)
    kq, ks = ref.quantize_kv(kp)
    vq, vs = ref.quantize_kv(vp)
    poison = pool - 1
    kq = kq.at[poison].set(127)
    ks = ks.at[poison].set(jnp.nan)
    vs = vs.at[poison].set(jnp.nan)
    q = jnp.asarray(rng.normal(size=(1, c, h, d)), jnp.float32)
    clean = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    starts = jnp.asarray([0], jnp.int32)
    counts = jnp.asarray([4], jnp.int32)           # uses 1 ctx page
    want = ops.paged_prefill_attention(
        q, kq, vq, clean, starts, counts, k_scale=ks, v_scale=vs, impl="ref"
    )
    dirty = clean.at[0, 1:].set(poison)
    got = ops.paged_prefill_attention(
        q, kq, vq, dirty, starts, counts, k_scale=ks, v_scale=vs,
        impl="pallas",
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


def test_fp32_accumulation_under_bf16_inputs():
    """bf16 q/kv still accumulate the softmax and pv products in fp32."""
    rng = np.random.default_rng(5)
    q, kp, vp, rows = _case(rng, r=2, c=4, h=4, kvh=2, d=16,
                            pool=8, page=4, ctx=2)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
    starts = jnp.asarray([0, 3], jnp.int32)
    counts = jnp.asarray([4, 4], jnp.int32)
    got, want = _both(qb, kb, vb, rows, starts, counts)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), rtol=2e-2, atol=2e-2
    )
