"""Allclose validation for the workload kernels (transpose/spmv/attention/MoE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(128, 128), (40, 72), (256, 64), (8, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_transpose(shape, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype=dtype)
    out = ops.tiled_transpose(x, block=32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x).T)


@pytest.mark.parametrize("r,k,c", [(8, 4, 32), (20, 6, 50), (64, 16, 256), (7, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_spmv_ell(r, k, c, dtype):
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=(r, k)), dtype=dtype)
    cols = jnp.asarray(rng.integers(0, c, (r, k)), dtype=jnp.int32)
    x = jnp.asarray(rng.normal(size=(c,)), dtype=dtype)
    np.testing.assert_allclose(
        np.asarray(ops.spmv_ell(vals, cols, x)),
        np.asarray(ref.spmv_ell(vals, cols, x)),
        rtol=1e-5, atol=1e-5,
    )


def test_spmv_csr_roundtrip():
    """CSR→ELL conversion + kernel matches dense matvec on a random sparse matrix."""
    rng = np.random.default_rng(2)
    n = 64
    dense = rng.normal(size=(n, n)) * (rng.random((n, n)) < 0.1)
    # Build CSR by hand (no scipy in this container).
    indptr = [0]
    indices, data = [], []
    for r in range(n):
        nz = np.nonzero(dense[r])[0]
        indices.extend(nz.tolist())
        data.extend(dense[r, nz].tolist())
        indptr.append(len(indices))
    vals, cols = ref.csr_to_ell(
        np.asarray(indptr), np.asarray(indices, np.int32),
        np.asarray(data, np.float32), n,
    )
    x = rng.normal(size=(n,)).astype(np.float32)
    y = ops.spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), dense.astype(np.float32) @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "b,h,kvh,sq,skv,d", [(1, 2, 2, 16, 16, 8), (2, 4, 2, 32, 32, 16), (1, 8, 1, 64, 64, 32)]
)
@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kvh, sq, skv, d, causal, window, dtype):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(b, kvh, skv, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(b, kvh, skv, d)), dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=8, block_k=8)
    expect = ref.mha(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), rtol=tol, atol=tol
    )


# (The paged-decode ref≡pallas spot checks that used to live here are
# subsumed by the dtype × GQA × lengths cross-product in
# test_oracle_sweep.py, which also carries the int8 quantization-error
# bound against the full-precision pool.)


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 48),
    e=st.sampled_from([2, 4, 8]),
    k=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_dispatch_combine_property(t, e, k, seed):
    """Property: with ample capacity, dispatch+identity+combine = gate-weighted sum."""
    rng = np.random.default_rng(seed)
    d = 64
    cap = t * k  # no drops
    tok = jnp.asarray(rng.normal(size=(t, d)), dtype=jnp.float32)
    eidx = jnp.asarray(rng.integers(0, e, (t, k)), dtype=jnp.int32)
    gw = jnp.asarray(rng.random((t, k)), dtype=jnp.float32)
    buf, src, keep = ops.moe_dispatch(tok, eidx, e, cap)
    assert bool(np.asarray(keep).all())
    out = ops.moe_combine(buf, src, gw, t)
    expect = tok * gw.sum(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4)


def test_moe_dispatch_matches_ref():
    rng = np.random.default_rng(6)
    t, d, e, k, cap = 24, 128, 4, 2, 8
    tok = jnp.asarray(rng.normal(size=(t, d)), dtype=jnp.float32)
    eidx = jnp.asarray(rng.integers(0, e, (t, k)), dtype=jnp.int32)
    b1, s1, k1 = ops.moe_dispatch(tok, eidx, e, cap)
    b2, s2, k2 = ref.moe_dispatch(tok, eidx, e, cap)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))


@pytest.mark.parametrize("causal,window,kvh", [(True, None, 2), (False, None, 4),
                                               (True, 8, 1)])
def test_flash_attention_trainable(causal, window, kvh):
    """The Pallas path's custom_vjp (FA2-style backward kernels) matches
    autodiff through the dense reference."""
    rng = np.random.default_rng(7)
    b, h, s, d = 2, 4, 32, 16
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kvh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kvh, s, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)

    def loss_pallas(q_, k_, v_):
        return jnp.sum(ops.flash_attention(
            q_, k_, v_, causal=causal, window=window, block_q=8, block_k=8) * w)

    def loss_ref(q_, k_, v_):
        return jnp.sum(ref.mha(q_, k_, v_, causal=causal, window=window) * w)

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)
