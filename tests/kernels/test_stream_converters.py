"""Per-kernel allclose validation: stream converters vs pure-jnp oracles.

Sweeps shapes/dtypes per the kernel-validation contract and adds
hypothesis property tests on the packing invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]
SHAPES = [(16, 128), (64, 128), (64, 256), (40, 384), (128, 512)]


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape)
    if dtype == jnp.int32:
        return jnp.asarray((x * 100).astype(np.int32))
    return jnp.asarray(x, dtype=dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("stride", [1, 2, 3, 5])
def test_strided_gather(shape, dtype, stride):
    rng = np.random.default_rng(0)
    src = _rand(rng, shape, dtype)
    count = max(1, (shape[0] - 1) // stride)
    out = ops.strided_gather(src, 0, stride, count)
    expect = ref.strided_gather(src, 0, stride, count)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("stride", [2, 4])
def test_strided_scatter(shape, dtype, stride):
    rng = np.random.default_rng(1)
    count = (shape[0] - 1) // stride
    packed = _rand(rng, (count, shape[1]), dtype)
    dst = _rand(rng, shape, dtype)
    out = ops.strided_scatter(dst, packed, 1, stride)
    expect = ref.strided_scatter(dst, packed, 1, stride)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("count", [1, 8, 23, 64])
def test_indirect_gather(shape, dtype, count):
    rng = np.random.default_rng(2)
    src = _rand(rng, shape, dtype)
    idx = jnp.asarray(rng.integers(0, shape[0], count), dtype=jnp.int32)
    out = ops.indirect_gather(src, idx)
    expect = ref.indirect_gather(src, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("count", [8, 13])
def test_indirect_scatter_unique(shape, dtype, count):
    rng = np.random.default_rng(3)
    packed = _rand(rng, (count, shape[1]), dtype)
    dst = _rand(rng, shape, dtype)
    idx = jnp.asarray(rng.permutation(shape[0])[:count], dtype=jnp.int32)
    out = ops.indirect_scatter(dst, packed, idx)
    expect = ref.indirect_scatter(dst, packed, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_indirect_scatter_preserves_untouched():
    dst = jnp.full((32, 128), 7.0)
    packed = jnp.zeros((4, 128))
    idx = jnp.asarray([1, 2, 3, 4], dtype=jnp.int32)
    out = ops.indirect_scatter(dst, packed, idx)
    assert np.allclose(np.asarray(out)[0], 7.0)
    assert np.allclose(np.asarray(out)[5:], 7.0)


@settings(max_examples=25, deadline=None)
@given(
    n_rows=st.integers(8, 64),
    count=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_scatter_roundtrip(n_rows, count, seed):
    """Property: scatter(gather(x, idx), idx) restores x at idx (unique idx)."""
    rng = np.random.default_rng(seed)
    count = min(count, n_rows)
    src = jnp.asarray(rng.normal(size=(n_rows, 128)), dtype=jnp.float32)
    idx = jnp.asarray(rng.permutation(n_rows)[:count], dtype=jnp.int32)
    packed = ops.indirect_gather(src, idx)
    restored = ops.indirect_scatter(jnp.zeros_like(src), packed, idx)
    np.testing.assert_allclose(
        np.asarray(restored)[np.asarray(idx)], np.asarray(src)[np.asarray(idx)]
    )


@settings(max_examples=25, deadline=None)
@given(
    stride=st.integers(2, 8),
    count=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_strided_equals_indirect_with_arange(stride, count, seed):
    """Property: a strided stream ≡ an indirect stream with arange indices."""
    rng = np.random.default_rng(seed)
    n = stride * count + 1
    src = jnp.asarray(rng.normal(size=(n, 128)), dtype=jnp.float32)
    a = ops.strided_gather(src, 0, stride, count)
    b = ops.indirect_gather(src, jnp.arange(count, dtype=jnp.int32) * stride)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
