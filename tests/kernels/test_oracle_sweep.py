"""Unified differential-oracle sweep: ref ≡ pallas across the cross-product.

One seeded, parametrized suite replacing the per-kernel ad-hoc ref≡pallas
cases (the GQA/int8 spot checks that used to live in test_paged_prefill.py
and test_workload_kernels.py): both paged kernels × {fp32, int8} ×
{GQA group 1/2/4} × ragged / page-boundary length patterns.  A future
kernel edit gets the full cross-product for free — a new length pattern or
GQA shape added below lands on every kernel and dtype at once.

Each case's RNG is seeded from its parameter id, so failures name the exact
cell and reproduce run-to-run; specials that don't fit a cross-product
(poison-page DMA clamps, bf16 accumulation, padding-row NaN guards) stay
with their kernel's dedicated test module.
"""
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

PAGE = 4
POOL = 24

#: GQA group size g = h / kvh — the grouping the kernels resolve per KV head.
GQA = {1: (4, 4), 2: (4, 2), 4: (8, 2)}

#: Decode length patterns over a 4-page table (max 16 tokens): ragged
#: mid-page lengths, exact page multiples (the off-by-one spot for the page
#: walk), inactive rows (length 0 — what a masked decode slot passes), a
#: single live token, and the completely full table.
DECODE_LENGTHS = {
    "ragged": [1, 7, 14],
    "page_multiple": [4, 8, 16],
    "with_inactive": [0, 5, 9],
    "minimal": [1, 1, 1],
    "full": [16, 16, 16],
}

#: Prefill (starts, counts) patterns with a chunk width of 8: ragged
#: mid-page starts, chunks straddling page boundaries, start+count landing
#: exactly on page boundaries, and padding rows (count 0, including a
#: degenerate non-zero start).
PREFILL_CHUNKS = {
    "ragged": ([0, 6, 3], [8, 8, 5]),
    "straddle": ([2, 7, 5], [6, 5, 3]),
    "page_multiple": ([0, 4, 12], [4, 4, 4]),
    "padding_rows": ([0, 12, 9], [8, 0, 0]),
}


def _seed(*parts) -> int:
    return zlib.crc32("/".join(str(p) for p in parts).encode())


def _pool(rng, kvh, d, quantize):
    kp = jnp.asarray(rng.normal(size=(POOL, PAGE, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(POOL, PAGE, kvh, d)), jnp.float32)
    if not quantize:
        return kp, vp, {}
    kq, ks = ref.quantize_kv(kp)
    vq, vs = ref.quantize_kv(vp)
    return kq, vq, dict(k_scale=ks, v_scale=vs)


@pytest.mark.parametrize("lengths_name", sorted(DECODE_LENGTHS))
@pytest.mark.parametrize("group", sorted(GQA))
@pytest.mark.parametrize("dtype", ["fp32", "int8"])
def test_paged_decode_matches_ref(dtype, group, lengths_name):
    h, kvh = GQA[group]
    d = 16
    lengths = DECODE_LENGTHS[lengths_name]
    b, npg = len(lengths), 4
    rng = np.random.default_rng(_seed("decode", dtype, group, lengths_name))
    kp, vp, scales = _pool(rng, kvh, d, dtype == "int8")
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    pt = jnp.asarray(
        rng.permutation(POOL)[: b * npg].reshape(b, npg), jnp.int32
    )
    ln = jnp.asarray(lengths, jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, pt, ln, impl="pallas", **scales)
    want = ops.paged_decode_attention(q, kp, vp, pt, ln, impl="ref", **scales)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )
    assert np.isfinite(np.asarray(got)).all()
    for i, n in enumerate(lengths):  # inactive rows must stay exact zeros
        if n == 0:
            assert np.abs(np.asarray(got)[i]).max() == 0.0


@pytest.mark.parametrize("chunk_name", sorted(PREFILL_CHUNKS))
@pytest.mark.parametrize("group", sorted(GQA))
@pytest.mark.parametrize("dtype", ["fp32", "int8"])
def test_paged_prefill_matches_ref(dtype, group, chunk_name):
    h, kvh = GQA[group]
    d = 16
    starts, counts = PREFILL_CHUNKS[chunk_name]
    r, c, ctx = len(starts), 8, 4
    rng = np.random.default_rng(_seed("prefill", dtype, group, chunk_name))
    kp, vp, scales = _pool(rng, kvh, d, dtype == "int8")
    q = jnp.asarray(rng.normal(size=(r, c, h, d)), jnp.float32)
    rows = jnp.asarray(
        rng.permutation(POOL)[: r * ctx].reshape(r, ctx), jnp.int32
    )
    st = jnp.asarray(starts, jnp.int32)
    ct = jnp.asarray(counts, jnp.int32)
    got = ops.paged_prefill_attention(
        q, kp, vp, rows, st, ct, impl="pallas", **scales
    )
    want = ops.paged_prefill_attention(
        q, kp, vp, rows, st, ct, impl="ref", **scales
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
    assert np.isfinite(np.asarray(got)).all()
    for i, n in enumerate(counts):  # padding rows must stay exact zeros
        if n == 0:
            assert np.abs(np.asarray(got)[i]).max() == 0.0


def test_int8_decode_quantization_error_bounded():
    """The int8 path tracks the full-precision pool closely (not just its
    own oracle): the end-to-end dequant error stays small, so serving from
    quantized pages is a bandwidth trade, not an accuracy cliff."""
    rng = np.random.default_rng(_seed("decode", "int8", "error"))
    h, kvh, d, npg = 4, 2, 16, 4
    kp = jnp.asarray(rng.normal(size=(POOL, PAGE, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(POOL, PAGE, kvh, d)), jnp.float32)
    kq, ks = ref.quantize_kv(kp)
    vq, vs = ref.quantize_kv(vp)
    b = 2
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    pt = jnp.asarray(
        rng.permutation(POOL)[: b * npg].reshape(b, npg), jnp.int32
    )
    ln = jnp.asarray([7, 14], jnp.int32)
    out = ops.paged_decode_attention(
        q, kq, vq, pt, ln, k_scale=ks, v_scale=vs, impl="pallas"
    )
    full = ops.paged_decode_attention(q, kp, vp, pt, ln, impl="ref")
    assert np.abs(np.asarray(out) - np.asarray(full)).max() < 0.05
