"""Multi-query speculative verify: kernel vs oracle, acceptance logic,
engine- and scheduler-level bit-equivalence with plain greedy decode.

``ops.paged_verify`` scores K draft tokens per sequence in one clamped
scalar-prefetched page walk — structurally a causal prefill chunk whose
``starts`` are the live lengths — so the ref/pallas sweep here mirrors the
prefill sweep with the verify calling convention (per-row ``lengths`` +
``counts``, ragged and page-straddling).  ``ops.speculative_accept`` is the
greedy accept rule (longest matched draft prefix + the model's bonus
token); the engine/scheduler tests assert the one property everything
rests on: emitted tokens are bitwise the plain greedy decode sequence for
every ``spec_k``, drafter quality notwithstanding — including K=1 (the
degenerate no-draft path) and under eviction/replay chaos.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels import ops, ref
from repro.serve import (
    PagedKVCache,
    PagedLM,
    Request,
    Scheduler,
    static_batch_generate,
)
from repro.serve.drafter import NGramDrafter, TinyLMDrafter
from repro.serve.faults import FaultPlan, check_scheduler_invariants

CFG = smoke_config("yi-6b")


def _sharpen(model):
    """Random-init smoke models collapse to a one-token greedy fixed point;
    amplified weights give varied sequences so equivalence is non-trivial."""
    model.params = {
        k: (v * 8.0 if k != "embed" else v * 3.0)
        for k, v in model.params.items()
    }
    return model


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------


def _verify_case(rng, b, k, h, kvh, d, pool, page, ctx, lengths, counts,
                 int8=False):
    kp = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, k, h, d)), jnp.float32)
    rows = jnp.asarray(
        rng.permutation(pool)[: b * ctx].reshape(b, ctx), jnp.int32
    )
    lengths = jnp.asarray(lengths, jnp.int32)
    counts = jnp.asarray(counts, jnp.int32)
    scales = {}
    if int8:
        kp, ks = ref.quantize_kv(kp)
        vp, vs = ref.quantize_kv(vp)
        scales = dict(k_scale=ks, v_scale=vs)
    return q, kp, vp, rows, lengths, counts, scales


@pytest.mark.parametrize("k", [1, 2, 4, 8])
@pytest.mark.parametrize("gqa", [1, 2, 4])
@pytest.mark.parametrize("int8", [False, True])
def test_matches_ref_sweep(k, gqa, int8):
    """K × GQA × dtype sweep over ragged verify chunks: per-row live
    lengths differ by pages, one row starts mid-page and straddles a page
    boundary, one lands exactly on a boundary, and a ``counts == 0``
    padding row stays all-zero (the capacity-clamp stall case)."""
    rng = np.random.default_rng(100 + k + 10 * gqa + 100 * int8)
    h, kvh, d, page, ctx = 4, 4 // gqa, 16, 4, 6
    lengths = [0, 3, 8, 13]               # fresh, mid-page, exact, straddle
    counts = [k, k, k, 0]
    q, kp, vp, rows, lens, cnts, scales = _verify_case(
        rng, b=4, k=k, h=h, kvh=kvh, d=d, pool=32, page=page, ctx=ctx,
        lengths=lengths, counts=counts, int8=int8,
    )
    want = ops.paged_verify(q, kp, vp, rows, lens, cnts, impl="ref",
                            **scales)
    got = ops.paged_verify(q, kp, vp, rows, lens, cnts, impl="pallas",
                           **scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()
    assert np.abs(np.asarray(got)[3]).max() == 0.0   # stalled row → zeros


def test_verify_is_prefill_at_the_tail():
    """The defining identity: a verify chunk over live length L IS a
    prefill chunk with ``starts = L`` — same oracle, same kernel, bit for
    bit (the engine's bit-exactness is by construction, not coincidence)."""
    rng = np.random.default_rng(9)
    q, kp, vp, rows, lens, cnts, _ = _verify_case(
        rng, b=3, k=4, h=4, kvh=2, d=16, pool=24, page=4, ctx=5,
        lengths=[2, 7, 12], counts=[4, 4, 3],
    )
    via_verify = ops.paged_verify(q, kp, vp, rows, lens, cnts, impl="ref")
    via_prefill = ops.paged_prefill_attention(
        q, kp, vp, rows, lens, cnts, impl="ref"
    )
    np.testing.assert_array_equal(np.asarray(via_verify),
                                  np.asarray(via_prefill))


# ---------------------------------------------------------------------------
# Acceptance rule
# ---------------------------------------------------------------------------


def _accept_oracle(drafts, greedy, counts):
    """Python re-statement of the greedy accept rule: the longest draft
    prefix matching the model's own argmax, plus one bonus token, capped
    by the scored count."""
    b, km1 = drafts.shape
    out = np.zeros((b,), np.int32)
    for i in range(b):
        a = 0
        while a < km1 and drafts[i, a] == greedy[i, a]:
            a += 1
        out[i] = min(a + 1, counts[i])
    return out


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_speculative_accept_matches_python_oracle(k):
    rng = np.random.default_rng(k)
    b = 16
    drafts = rng.integers(0, 3, (b, k - 1)).astype(np.int32)
    greedy = rng.integers(0, 3, (b, k)).astype(np.int32)
    counts = rng.integers(0, k + 1, (b,)).astype(np.int32)
    got = np.asarray(ops.speculative_accept(
        jnp.asarray(drafts), jnp.asarray(greedy), jnp.asarray(counts)
    ))
    np.testing.assert_array_equal(got, _accept_oracle(drafts, greedy, counts))


def test_speculative_accept_truncates_at_first_mismatch():
    """Tokens after the first mismatch never count, even if they match."""
    drafts = jnp.asarray([[5, 9, 7]], jnp.int32)
    greedy = jnp.asarray([[5, 1, 7, 3]], jnp.int32)   # mismatch at column 1
    n = ops.speculative_accept(drafts, greedy, jnp.asarray([4], jnp.int32))
    assert int(n[0]) == 2                              # matched prefix + bonus
    # All match → everything plus the bonus token.
    n = ops.speculative_accept(
        drafts, jnp.asarray([[5, 9, 7, 3]], jnp.int32),
        jnp.asarray([4], jnp.int32),
    )
    assert int(n[0]) == 4
    # Clamp: capacity caps the emission below the matched prefix.
    n = ops.speculative_accept(
        drafts, jnp.asarray([[5, 9, 7, 3]], jnp.int32),
        jnp.asarray([2], jnp.int32),
    )
    assert int(n[0]) == 2


def test_speculative_accept_k1_degenerates_to_plain_decode():
    """K=1: zero drafts, so every active row emits exactly its bonus token
    — the plain decode step in speculative clothing."""
    drafts = jnp.zeros((3, 0), jnp.int32)
    greedy = jnp.asarray([[4], [2], [9]], jnp.int32)
    counts = jnp.asarray([1, 1, 0], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ops.speculative_accept(drafts, greedy, counts)),
        [1, 1, 0],
    )


# ---------------------------------------------------------------------------
# Engine: verify_upto ≡ decode_upto, bit for bit
# ---------------------------------------------------------------------------


def _engine(spec_k, kv_dtype=None, drafter=None, seed=3):
    model = _sharpen(PagedLM(CFG, jax.random.PRNGKey(seed), impl="ref",
                             spec_k=spec_k, kv_dtype=kv_dtype,
                             drafter=drafter))
    cache = PagedKVCache.create(CFG, batch=2, max_len=64, page=8,
                                kv_dtype=kv_dtype)
    prompts = [np.arange(1, 6, dtype=np.int32) % CFG.vocab,
               (np.arange(11, 23, dtype=np.int32) * 7) % CFG.vocab]
    feed = np.zeros((2,), np.int32)
    for s, p in enumerate(prompts):
        cache = cache.allocate(s, cache.pages_for(64))
        for start in range(0, len(p), 8):
            cnt = min(8, len(p) - start)
            buf = np.zeros((8,), np.int32)
            buf[:cnt] = p[start:start + cnt]
            logits, cache = model.prefill_chunk(
                jnp.asarray(buf), cnt, s, start, cache
            )
        feed[s] = int(np.argmax(np.asarray(logits)[: CFG.vocab]))
    return model, cache, feed


def _spec_tokens(model, cache, feed, n_steps, total):
    """Flatten a verify_upto run's emissions per slot, first ``total``."""
    active = np.ones((2,), bool)
    dstate = model.drafter.init_state(2)
    toks, counts, cache, _ = model.verify_upto(
        feed, cache, active, n_steps, dstate
    )
    out = []
    for s in range(2):
        flat = []
        for step in range(toks.shape[0]):
            flat.extend(int(t) for t in toks[step, s, : counts[step, s]])
        out.append(flat[:total])
    return out


@pytest.mark.parametrize("spec_k", [1, 2, 4, 8])
@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_engine_emits_plain_greedy_sequence(spec_k, kv_dtype):
    """verify_upto's emitted stream equals decode_upto's, bitwise, for
    every K and pool dtype — first tokens of the two feeds included."""
    total = 16
    model, cache, feed = _engine(1, kv_dtype)
    plain, _ = model.decode_upto(feed, cache, np.ones((2,), bool), total)
    want = [[int(t) for t in plain[:, s]] for s in range(2)]

    model, cache, feed = _engine(spec_k, kv_dtype)
    # Enough steps to emit ``total`` even at the 1-token-per-step floor.
    got = _spec_tokens(model, cache, feed, total, total)
    assert got == want


def test_engine_equivalence_is_drafter_independent():
    """A different drafter changes acceptance, never bits: the n-gram and
    tiny-LM drafters emit identical streams (the correctness/performance
    separation the replay story depends on)."""
    total = 12
    draft_embed = _sharpen(
        PagedLM(CFG, jax.random.PRNGKey(7), impl="ref")
    ).params["embed"]
    outs = []
    for drafter in (None, TinyLMDrafter(draft_embed, vocab=CFG.vocab)):
        model, cache, feed = _engine(4, drafter=drafter)
        outs.append(_spec_tokens(model, cache, feed, total, total))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Scheduler: spec_k > 1 ≡ static batch, including chaos replay
# ---------------------------------------------------------------------------


def _sched_model(spec_k, seed=3):
    return _sharpen(PagedLM(CFG, jax.random.PRNGKey(seed), impl="ref",
                            spec_k=spec_k))


def _sched_prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(0, CFG.vocab, n).astype(np.int32)
            for n in (5, 12, 9)]


@pytest.mark.parametrize("spec_k", [2, 4])
def test_scheduler_matches_static_batch(spec_k):
    prompts = _sched_prompts()
    max_new = 12
    want = static_batch_generate(
        _sched_model(1),
        PagedKVCache.create(CFG, batch=4, max_len=64, page=8),
        prompts, max_new, chunk=8,
    )
    cache = PagedKVCache.create(CFG, batch=4, max_len=64, page=8)
    sched = Scheduler(_sched_model(spec_k), cache, chunk=8)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=max_new))
    out = sched.run()
    assert out == want
    st = sched.stats
    assert st.spec_steps > 0
    assert st.n_drafted > 0 and st.n_accepted > 0
    assert st.n_emitted == sum(len(v) for v in out.values()) - len(out)
    assert 0.0 < st.acceptance_rate <= 1.0
    # Verify launches carry decode-side traffic accounting.
    assert st.pack_bytes > 0 and st.base_bytes > 0 and st.useful_bytes > 0


def test_scheduler_spec_k1_is_plain_decode_path():
    """spec_k=1 never calls the verify path: records and outputs are the
    plain fused-decode ones (kind='decode' only, zero draft accounting)."""
    prompts = _sched_prompts()
    cache = PagedKVCache.create(CFG, batch=4, max_len=64, page=8)
    sched = Scheduler(_sched_model(1), cache, chunk=8)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=8))
    out = sched.run()
    assert sched.stats.spec_steps == 0
    assert sched.stats.n_drafted == 0
    assert all(len(v) == 8 for v in out.values())


def test_eviction_mid_speculation_replays_bit_for_bit():
    """A pool too small for all residents forces evictions between verify
    launches; replay re-prefills and re-feeds through the same speculative
    path and must reproduce the unconstrained outputs exactly.  Replay
    charges only accepted (emitted) tokens: replay_spent counts
    prompt + generated, never the rejected drafts."""
    prompts = _sched_prompts()
    max_new = 14
    roomy, _ = _run_sched(4, prompts, max_new, pool_pages=None)
    tight, sched = _run_sched(4, prompts, max_new, pool_pages=6)
    assert tight == roomy
    assert sched.stats.n_evictions > 0
    for r in list(sched.finished.values()):
        assert r.replay_spent <= r.n_evictions * (r.prompt_len + max_new)


def _run_sched(spec_k, prompts, max_new, pool_pages=None, faults=None):
    kw = {} if pool_pages is None else dict(pool_pages=pool_pages)
    cache = PagedKVCache.create(CFG, batch=4, max_len=64, page=8, **kw)
    sched = Scheduler(_sched_model(spec_k), cache, chunk=8, faults=faults)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    if faults is not None:
        while sched.queue or sched.resident:
            sched.step()
            check_scheduler_invariants(sched, reqs)
        out = {rid: r.generated for rid, r in sorted(sched.finished.items())}
    else:
        out = sched.run()
    return out, sched


def test_chaos_faults_with_speculation():
    """The chaos seed case: injected exhaustion/denial during speculative
    serving degrades through the same ladder and stays bit-for-bit."""
    prompts = _sched_prompts()
    want, _ = _run_sched(1, prompts, 12)
    plan = FaultPlan.random(200, n_steps=30)
    got, sched = _run_sched(4, prompts, 12, pool_pages=10, faults=plan)
    assert got == want
    sched.family.check_integrity()


# ---------------------------------------------------------------------------
# Jit-program LRU: verify buckets share the prefill cache
# ---------------------------------------------------------------------------


def test_verify_jits_share_bounded_lru():
    """Verify programs are keyed ('verify', spec_k, page, ctx) in the *same*
    bounded LRU as the (page, ctx) prefill buckets: a (page × launch-width)
    sweep mints prefill and verify keys past the cap, the cache never
    exceeds it, and an evicted verify bucket transparently re-jits with
    identical emitted tokens."""
    model = _sched_model(4)
    model.prefill_cache_cap = 3
    prompts = [np.arange(1, 6, dtype=np.int32) % CFG.vocab,
               (np.arange(11, 23, dtype=np.int32) * 7) % CFG.vocab]

    def spec_run(page, n_steps):
        cache = PagedKVCache.create(CFG, batch=2, max_len=64, page=page)
        feed = np.zeros((2,), np.int32)
        for s, p in enumerate(prompts):
            cache = cache.allocate(s, cache.pages_for(64))
            logits, cache = model.prefill_chunk(
                jnp.asarray(p), len(p), s, 0, cache
            )
            feed[s] = int(np.argmax(np.asarray(logits)[: CFG.vocab]))
        return _spec_tokens(model, cache, feed, n_steps, 4)

    keys_seen = set()
    outs = {}
    for combo in ((4, 1), (4, 8), (8, 1), (8, 8)):
        outs[combo] = spec_run(*combo)
        keys_seen |= set(model._prefill_cache)
        assert len(model._prefill_cache) <= 3       # cap always holds
    verify_keys = {k for k in keys_seen if k[0] == "verify"}
    prefill_keys = keys_seen - verify_keys
    assert verify_keys and prefill_keys             # both kinds share the LRU
    assert all(k[1] == 4 for k in verify_keys)      # keyed by spec_k
    assert len(keys_seen) > 3                       # sweep minted past cap
    assert set(model._prefill_cache) < keys_seen    # something was evicted
    # Re-running the first (now evicted) bucket re-jits and reproduces its
    # emitted tokens exactly.
    assert spec_run(4, 1) == outs[(4, 1)]
    assert len(model._prefill_cache) <= 3
