"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting output shapes and no NaNs.

Full assigned configs are exercised only via the dry-run (no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCH_NAMES, applicable_shapes, get_config, smoke_config
from repro.models import lm
from repro.parallel.sharding import make_rules

RULES = make_rules(with_pod=False)
B, S = 2, 32


def _batch(cfg, rng, s=S):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, s)))
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (B, s)))
    batch = {"tokens": tokens, "targets": targets, "mask": jnp.ones((B, s))}
    if cfg.modality == "audio":
        batch = {
            "frontend": jnp.asarray(rng.normal(size=(B, s, cfg.frontend_dim)), jnp.float32),
            "targets": targets,
            "mask": jnp.ones((B, s)),
        }
    elif cfg.modality == "vlm":
        batch["tokens"] = tokens[:, : s - cfg.frontend_len]
        batch["frontend"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ALL_ARCH_NAMES)
def test_forward_and_grad(name):
    cfg = smoke_config(name)
    rng = np.random.default_rng(0)
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    def lossfn(p):
        loss, metrics = lm.train_loss(p, batch, cfg, RULES)
        return loss, metrics

    (loss, metrics), grads = jax.jit(jax.value_and_grad(lossfn, has_aux=True))(params)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{name}: NaN loss"
    assert 1.0 < float(loss) < 25.0, f"{name}: implausible init loss {loss}"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(not bool(jnp.any(jnp.isnan(g))) for g in leaves), f"{name}: NaN grads"
    # At least 99% of parameter tensors receive nonzero gradient.
    nz = sum(bool(jnp.any(g != 0)) for g in leaves)
    assert nz >= 0.9 * len(leaves), f"{name}: {nz}/{len(leaves)} grads nonzero"


@pytest.mark.parametrize("name", ALL_ARCH_NAMES)
def test_decode_matches_full_forward(name):
    """Prefill + per-token decode ≡ full forward (caches are exact)."""
    cfg = smoke_config(name)
    if not cfg.causal:
        pytest.skip("encoder-only: no decode step (DESIGN.md §4)")
    # Large capacity factor: MoE capacity drops are by-design train-path
    # behaviour; exactness is asserted in the no-drop regime.
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    tol = 0.05 if cfg.cache_dtype == "int8" else 2e-4  # int8: quantized cache
    rng = np.random.default_rng(1)
    params = lm.init_model(cfg, jax.random.PRNGKey(1))
    s = 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, s)))

    from repro.models.common import rms_norm
    from repro.models.lm import _block_train, embed_tokens, global_flags, output_weight

    x = embed_tokens(params, {"tokens": tokens}, cfg, RULES)
    flags = jnp.asarray(global_flags(cfg), jnp.float32)
    positions = jnp.arange(s)

    def step(c, xs):
        lp, fl = xs
        y, _ = _block_train(lp, c, cfg, RULES, fl, positions)
        return y, None

    xs_, _ = jax.lax.scan(step, x, (params["layers"], flags))
    full = rms_norm(xs_, params["final_norm"]) @ output_weight(params, cfg).astype(
        cfg.compute_dtype
    )

    p_len = s // 2
    cache = lm.init_cache(cfg, B, s)
    lg, cache = lm.prefill(params, {"tokens": tokens[:, :p_len]}, cache, cfg, RULES)
    errs = [float(np.abs(np.asarray(lg[:, 0]) - np.asarray(full[:, p_len - 1])).max())]
    for t in range(p_len, s):
        lg, cache = lm.decode_step(params, tokens[:, t : t + 1], cache, t, cfg, RULES)
        errs.append(float(np.abs(np.asarray(lg) - np.asarray(full[:, t])).max()))
    assert max(errs) < tol, f"{name}: decode divergence {max(errs)}"


@pytest.mark.parametrize("name", ALL_ARCH_NAMES)
def test_exact_config_matches_assignment(name):
    """The registry carries the exact assigned hyperparameters."""
    cfg = get_config(name)
    expect = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == expect
    if name == "olmoe-1b-7b":
        assert (cfg.n_experts, cfg.top_k) == (64, 8)
    if name == "arctic-480b":
        assert (cfg.n_experts, cfg.top_k, cfg.dense_residual) == (128, 2, True)
    if name == "gemma3-27b":
        assert cfg.global_interval == 6 and cfg.window == 1024
    if name == "hubert-xlarge":
        assert not cfg.causal
    if name == "rwkv6-3b":
        assert cfg.ssm == "rwkv6"
    if name == "hymba-1.5b":
        assert cfg.ssm == "hymba" and cfg.ssm_state == 16


def test_shape_skips_are_principled():
    """Shape-cell applicability matches DESIGN.md §4 (32 live cells)."""
    total = 0
    for name in ALL_ARCH_NAMES:
        cfg = get_config(name)
        shapes = {s.name for s in applicable_shapes(cfg)}
        total += len(shapes)
        if name == "hubert-xlarge":
            assert shapes == {"train_4k", "prefill_32k"}
        elif name in ("rwkv6-3b", "hymba-1.5b", "gemma3-27b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes and "decode_32k" in shapes
    assert total == 32


def test_moe_capacity_drop_accounting():
    """Dropped assignments are reported and bounded by capacity math."""
    cfg = smoke_config("olmoe-1b-7b")
    rng = np.random.default_rng(2)
    from repro.kernels import ref as kref

    t, k = 128, cfg.top_k
    tok = jnp.asarray(rng.normal(size=(t, cfg.d_model)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, cfg.n_experts, (t, k)), jnp.int32)
    cap = 8
    _, _, keep = kref.moe_dispatch(tok, idx, cfg.n_experts, cap)
    kept = int(np.asarray(keep).sum())
    assert kept <= cfg.n_experts * cap
    assert kept >= min(t * k, cfg.n_experts * cap) * 0.5
