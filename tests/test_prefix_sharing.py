"""Property-based invariant fuzzer for prefix sharing + copy-on-write.

Random overlapping-prefix traffic drives the scheduler end-to-end (admission
→ prefill → decode → evict → replay → retire) and the pool's ownership
invariants are asserted after *every* scheduler step:

* **refcount conservation** — the refcount total equals the page-table
  mappings (per-slot ``mapped``) plus the prefix index's retentions; no
  page is simultaneously free and owned; free + owned partition the pool.
* **no leaks** — after the run drains and the prefix cache is flushed, the
  pool is back to all-free with every refcount at zero.
* **bit-for-bit outputs** — the sharing scheduler, the non-sharing
  scheduler, and ``static_batch_generate`` agree exactly, fp32 and int8
  (the replay contract: shared mappings are re-derived, never re-filled
  differently).

Runs under the real ``hypothesis`` package or the deterministic stub in
tests/_hypothesis_stub.py (CI runs both, the stub leg with
``REPRO_STUB_MAX_EXAMPLES=25``).  Alongside the fuzzer sit deterministic
regressions for the sharp edges: copy-on-write on fully page-aligned
matches, ``trim`` on shared pages (decrement, never free), retained-prefix
reuse after retirement, and in-flight admission deferral.
"""
import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs import smoke_config
from repro.serve import (
    FaultPlan,
    PagedKVCache,
    PagedLM,
    Request,
    Scheduler,
    check_scheduler_invariants,
    static_batch_generate,
)

CFG = smoke_config("yi-6b")
PAGE = 4
MAX_LEN = 32
MODELS = {
    "fp32": PagedLM(CFG, jax.random.PRNGKey(0), impl="ref"),
    "int8": PagedLM(CFG, jax.random.PRNGKey(0), impl="ref", kv_dtype="int8"),
}
KV_DTYPE = {"fp32": None, "int8": "int8"}


def check_invariants(sched: Scheduler, requests=None) -> None:
    # The full oracle lives in repro.serve.faults (conservation, free/owned
    # partition, slot bookkeeping, terminal-state discipline); raising
    # InvariantViolation (an AssertionError) keeps pytest semantics.
    check_scheduler_invariants(sched, requests)


def drive(sched: Scheduler, requests, max_steps: int = 400):
    """sched.run(), but with the invariants checked after every step."""
    for r in requests:
        sched.submit(r)
    steps = 0
    while sched.queue or sched.resident:
        sched.step()
        check_invariants(sched, requests)
        steps += 1
        assert steps < max_steps, "scheduler stalled"
    return {rid: r.generated for rid, r in sorted(sched.finished.items())}


def make_prompts(rng, n_reqs: int, sys_pages: int, max_new: int):
    """Overlapping-prefix mix: a shared system prompt (``sys_pages`` full
    pages) with random tails, plus occasional fully-random prompts."""
    sys_prompt = rng.integers(0, CFG.vocab, sys_pages * PAGE, dtype=np.int64)
    prompts = []
    for _ in range(n_reqs):
        if sys_pages and rng.random() < 0.75:
            tail = rng.integers(0, CFG.vocab, int(rng.integers(0, 6)),
                                dtype=np.int64)
            p = np.concatenate([sys_prompt, tail])
        else:
            p = rng.integers(0, CFG.vocab, int(rng.integers(1, 11)),
                             dtype=np.int64)
        p = p if len(p) else rng.integers(0, CFG.vocab, 1, dtype=np.int64)
        assert len(p) + max_new - 1 <= MAX_LEN
        prompts.append(np.asarray(p, np.int32))
    return prompts


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    n_reqs=st.integers(min_value=1, max_value=4),
    sys_pages=st.integers(min_value=0, max_value=2),
    max_new=st.integers(min_value=1, max_value=4),
    pool_extra=st.integers(min_value=0, max_value=6),
    kv=st.sampled_from(["fp32", "int8"]),
    chaos=st.booleans(),
)
def test_random_traffic_invariants_and_equivalence(
    seed, n_reqs, sys_pages, max_new, pool_extra, kv, chaos
):
    rng = np.random.default_rng(seed)
    prompts = make_prompts(rng, n_reqs, sys_pages, max_new)
    model = MODELS[kv]
    batch = min(n_reqs, 3)
    # Pool from tight (worst single request — maximum eviction/replay and
    # retention-drop pressure) to roomy.
    worst = max(-(-(len(p) + max_new - 1) // PAGE) for p in prompts)
    pool = worst + pool_extra
    reqs = lambda: [
        Request(rid=i, prompt=p, max_new=max_new)
        for i, p in enumerate(prompts)
    ]
    # Chaos leg: a seeded fault plan (forced exhaustion, denied allocations,
    # prefix drops) runs under BOTH schedulers — faults degrade scheduling,
    # never outputs, so every equality below must still hold.
    faults = FaultPlan.random(seed + 1, n_steps=16) if chaos else None

    def run(sharing: bool):
        cache = PagedKVCache.create(
            CFG, batch=batch, max_len=MAX_LEN, page=PAGE,
            pool_pages=pool, kv_dtype=KV_DTYPE[kv],
        )
        sched = Scheduler(model, cache, chunk=3, prefix_sharing=sharing,
                          faults=faults)
        return drive(sched, reqs()), sched

    out_shared, sched = run(True)
    out_plain, _ = run(False)
    assert out_shared == out_plain, "sharing changed outputs"

    static_cache = PagedKVCache.create(
        CFG, batch=n_reqs, max_len=MAX_LEN, page=PAGE,
        pool_pages=n_reqs * (MAX_LEN // PAGE), kv_dtype=KV_DTYPE[kv],
    )
    static = static_batch_generate(model, static_cache, prompts, max_new,
                                   chunk=3)
    assert out_shared == dict(static), "scheduler diverged from static batch"

    # No leaks: drained run + flushed prefix cache → pool all-free.
    check_invariants(sched)
    sched.flush_prefix_cache()
    assert sorted(sched.cache.free) == list(range(pool))
    assert int(sched.cache.refcounts.sum()) == 0
    # Accounting coherence: sharing recorded ⇔ pages were shared.
    assert (sched.stats.prefill_tokens_saved > 0) == (
        sched.stats.shared_pages > 0
    )


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    n_ops=st.integers(min_value=1, max_value=60),
)
def test_refcount_lifecycle_fuzz(seed, n_ops):
    """Engine-level fuzz of allocate/share/trim/release/retain/CoW: refcount
    conservation holds after every operation, with the retained set tracked
    shadow-side (no model, no scheduler — the bookkeeping alone)."""
    rng = np.random.default_rng(seed)
    batch, pool = 3, 10
    cache = PagedKVCache.create(
        CFG, batch=batch, max_len=MAX_LEN, page=PAGE, pool_pages=pool
    )
    retained: list = []

    def conserved():
        assert int(cache.refcounts.sum()) == (
            int(cache.mapped.sum()) + len(retained)
        )
        owned = {p for p in range(pool) if cache.refcounts[p] > 0}
        assert not (owned & set(cache.free))
        assert len(owned) + len(cache.free) == pool

    for _ in range(n_ops):
        op = rng.choice(["alloc", "share", "trim", "release", "retain",
                         "unretain", "cow"])
        seq = int(rng.integers(0, batch))
        used = int(cache.mapped[seq])
        if op == "alloc":
            n = int(rng.integers(1, 3))
            if n <= cache.n_free and used + n <= cache.pages_per_seq:
                cache = cache.allocate(seq, n)
        elif op == "share":
            src = int(rng.integers(0, batch))
            n_src = int(cache.mapped[src])
            if src != seq and n_src and used + n_src <= cache.pages_per_seq:
                ids = [int(p) for p in cache.page_table_host[src, :n_src]]
                cache = cache.share(seq, ids)
        elif op == "trim":
            cache = cache.trim(seq, int(rng.integers(0, used + 1)))
        elif op == "release":
            cache = cache.release(seq)
        elif op == "retain" and used:
            p = int(cache.page_table_host[seq, int(rng.integers(0, used))])
            cache = cache.retain_pages([p])
            retained.append(p)
        elif op == "unretain" and retained:
            p = retained.pop(int(rng.integers(0, len(retained))))
            cache = cache.release_pages([p])
        elif op == "cow" and used:
            hi = used * PAGE - 1
            try:
                cache, _ = cache.ensure_writable(seq, 0, hi)
            except Exception as e:
                assert "copy-on-write needs" in str(e)
        conserved()

    for seq in range(batch):
        cache = cache.release(seq)
    cache = cache.release_pages(retained)
    retained.clear()
    conserved()
    assert sorted(cache.free) == list(range(pool))


def test_cow_on_page_aligned_full_match():
    """A prompt that fully matches a page-multiple indexed prefix must
    copy-on-write its final shared page (the re-prefilled last token writes
    there) — and still reproduce the unshared outputs exactly."""
    model = MODELS["fp32"]
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, CFG.vocab, 2 * PAGE).astype(np.int32)
    prompts = [prompt.copy(), prompt.copy(), prompt.copy()]

    def run(sharing):
        cache = PagedKVCache.create(CFG, batch=3, max_len=MAX_LEN, page=PAGE)
        sched = Scheduler(model, cache, chunk=3, prefix_sharing=sharing)
        return drive(sched, [
            Request(rid=i, prompt=p, max_new=3)
            for i, p in enumerate(prompts)
        ]), sched

    out_shared, sched = run(True)
    out_plain, _ = run(False)
    assert out_shared == out_plain
    assert sched.stats.cow_copies >= 1, "full-page match must trigger CoW"
    assert sched.stats.prefill_tokens_saved > 0


def test_trim_shared_page_decrements_not_frees():
    """Regression (the shared-page trim bug): trimming a sequence whose
    pages a prefix sibling still references must drop only this sequence's
    ownership — the page stays out of the free pool until the last owner
    lets go, and the sibling's KV mapping stays intact."""
    cache = PagedKVCache.create(CFG, batch=2, max_len=MAX_LEN, page=PAGE,
                                pool_pages=8)
    cache = cache.allocate(0, 2)
    pages = [int(p) for p in cache.page_table_host[0, :2]]
    cache = cache.share(1, pages)
    assert all(cache.refcounts[p] == 2 for p in pages)

    cache = cache.trim(0, 0)  # would free both pages without refcounts
    assert not (set(pages) & set(cache.free)), "trim freed shared pages"
    assert all(cache.refcounts[p] == 1 for p in pages)
    assert [int(p) for p in cache.page_table_host[1, :2]] == pages

    cache = cache.release(1)  # last owner → now they free
    assert set(pages) <= set(cache.free)
    assert int(cache.refcounts.sum()) == 0


def test_retained_prefix_reused_after_retirement():
    """The prefix cache outlives its author: a request admitted after the
    original has fully retired still maps the retained pages."""
    model = MODELS["fp32"]
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, CFG.vocab, 2 * PAGE + 2).astype(np.int32)

    cache = PagedKVCache.create(CFG, batch=2, max_len=MAX_LEN, page=PAGE)
    sched = Scheduler(model, cache, chunk=4, prefix_sharing=True)
    first = drive(sched, [Request(rid=0, prompt=prompt, max_new=3)])
    assert not sched.resident and len(sched.prefix_index.entries) == 2

    second = drive(sched, [Request(rid=1, prompt=prompt.copy(), max_new=3)])
    assert sched.stats.prefill_tokens_saved >= 2 * PAGE
    assert second[1] == first[0]  # same prompt, same tokens


def test_concurrent_identical_prompts_share_via_deferral():
    """Simultaneously submitted requests with one system prompt still share:
    admission defers the later arrivals one boundary while the first
    prefills, then maps its registered pages."""
    model = MODELS["fp32"]
    rng = np.random.default_rng(13)
    sys_prompt = rng.integers(0, CFG.vocab, 2 * PAGE)
    prompts = [
        np.concatenate([sys_prompt, rng.integers(0, CFG.vocab, t)])
        .astype(np.int32)
        for t in (2, 3, 4)
    ]
    cache = PagedKVCache.create(CFG, batch=3, max_len=MAX_LEN, page=PAGE)
    sched = Scheduler(model, cache, chunk=4, prefix_sharing=True)
    out = drive(sched, [
        Request(rid=i, prompt=p, max_new=2) for i, p in enumerate(prompts)
    ])
    assert sched.stats.prefill_tokens_saved >= 2 * 2 * PAGE  # rids 1 and 2
    plain_cache = PagedKVCache.create(CFG, batch=3, max_len=MAX_LEN,
                                      page=PAGE)
    plain = Scheduler(model, plain_cache, chunk=4)
    out_plain = drive(plain, [
        Request(rid=i, prompt=p.copy(), max_new=2)
        for i, p in enumerate(prompts)
    ])
    assert out == out_plain


def test_prefix_sharing_requires_refcounted_cache():
    cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
    import dataclasses
    legacy = dataclasses.replace(cache, refcounts=None)
    with pytest.raises(ValueError, match="refcounted"):
        Scheduler(MODELS["fp32"], legacy, prefix_sharing=True)
