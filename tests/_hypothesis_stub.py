"""Deterministic stand-in for the ``hypothesis`` package.

The property tests in this repo use a small slice of hypothesis
(``@given``/``@settings`` plus the ``integers``/``sampled_from``/``booleans``/
``floats`` strategies).  When the real package is installed it is always
preferred (see ``conftest.py``); this stub only exists so that ``pytest -x -q``
collects and runs in minimal environments (e.g. CI images without optional
dev dependencies).

Semantics: each ``@given`` test runs a fixed number of deterministically
pseudo-random examples (default 5, override with
``REPRO_STUB_MAX_EXAMPLES``).  Draw #0 probes the strategy's lower bound /
first choice so boundary cases are always covered; later draws are seeded by
the test's qualified name, so failures reproduce run-to-run.  There is no
shrinking — a failing example is reported as a plain pytest failure with the
drawn kwargs visible in the traceback.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import types
import zlib

__version__ = "0.0-repro-stub"

_MAX_EXAMPLES = int(os.environ.get("REPRO_STUB_MAX_EXAMPLES", "5"))


class _Strategy:
    """A draw function plus an explicit boundary example (draw #0)."""

    def __init__(self, draw, boundary):
        self._draw = draw
        self._boundary = boundary

    def example(self, rng: random.Random, index: int):
        if index == 0:
            return self._boundary()
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value), lambda: min_value)


def sampled_from(elements) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda r: elems[r.randrange(len(elems))], lambda: elems[0])


def booleans() -> _Strategy:
    return _Strategy(lambda r: bool(r.getrandbits(1)), lambda: False)


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value), lambda: min_value)


def just(value) -> _Strategy:
    return _Strategy(lambda r: value, lambda: value)


def lists(elem: _Strategy, min_size: int = 0, max_size: int = 8) -> _Strategy:
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elem.example(r, 1) for _ in range(n)]

    return _Strategy(draw, lambda: [elem.example(random.Random(0), 0)] * min_size)


strategies = types.SimpleNamespace(
    integers=integers,
    sampled_from=sampled_from,
    booleans=booleans,
    floats=floats,
    just=just,
    lists=lists,
)


class HealthCheck:
    """Name-compatible stand-ins for the real package's HealthCheck enum
    (the stub runs no health checks, so ``suppress_health_check`` lists are
    accepted and ignored)."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


def settings(max_examples: int | None = None, deadline=None, **_kw):
    """Record ``max_examples``; the stub caps it at REPRO_STUB_MAX_EXAMPLES."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            requested = getattr(wrapper, "_stub_max_examples", None)
            n = min(requested or _MAX_EXAMPLES, _MAX_EXAMPLES)
            base_seed = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = random.Random(base_seed * 1000003 + i)
                drawn = {
                    name: strat.example(rng, i)
                    for name, strat in sorted(strats.items())
                }
                fn(*args, **{**kwargs, **drawn})

        # pytest must not mistake the drawn arguments for fixtures: hide the
        # wrapped signature (functools.wraps exposes it via __wrapped__).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
