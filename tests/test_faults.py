"""Chaos suite: the scheduler survives pressure and faults.

The robustness contract under test (ISSUE 7 acceptance criteria):

* a forced pool-exhaustion chaos run completes with **zero crashes**;
* every submitted request ends in **exactly one terminal state**
  (finished / preempted / rejected);
* outputs of non-preempted requests are **bit-for-bit equal** to the
  fault-free run (eviction replay, prefix re-prefill, and deferred
  allocation are all invisible to the tokens);
* the step-wise invariant checker (`repro.serve.faults`) **never fires** —
  pool free/owned partition, refcount conservation, slot bookkeeping, and
  host-shadow consistency hold after every single step;
* all of the above in fp32 and int8, with and without prefix sharing.

CI runs this file over a seed matrix via ``REPRO_CHAOS_SEED_BASE``.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.runtime import FaultToleranceConfig, StragglerWatchdog
from repro.serve import (
    FaultPlan,
    InvariantViolation,
    PagedKVCache,
    PagedLM,
    RejectReason,
    Request,
    RequestState,
    Scheduler,
    check_scheduler_invariants,
    terminal_states,
)

CFG = smoke_config("yi-6b")
PAGE = 4
MAX_LEN = 32
MODELS = {
    "fp32": PagedLM(CFG, jax.random.PRNGKey(0), impl="ref"),
    "int8": PagedLM(CFG, jax.random.PRNGKey(0), impl="ref", kv_dtype="int8"),
}
KV_DTYPE = {"fp32": None, "int8": "int8"}

# CI shifts the chaos seed window per matrix job; locally it is seeds 0..N.
SEED_BASE = int(os.environ.get("REPRO_CHAOS_SEED_BASE", "0"))
SEEDS_PER_CASE = 3


def chaos_drive(sched, requests, max_steps: int = 500):
    """Drive to drain with the invariant oracle asserted after EVERY step.

    Submissions are non-strict: rejection is a terminal outcome here, not
    an error.  Returns finished outputs only.
    """
    for r in requests:
        sched.submit(r, strict=False)
    check_scheduler_invariants(sched, requests)
    steps = 0
    while sched.queue or sched.resident:
        sched.step()
        check_scheduler_invariants(sched, requests)
        steps += 1
        assert steps < max_steps, "chaos run failed to drain (deadlock)"
    return {rid: r.generated for rid, r in sorted(sched.finished.items())}


def _mk_requests(rng, n_reqs: int, max_new: int, sys_pages: int = 1,
                 priorities=(0, 1), budget_every: int = 3):
    """Mixed traffic: shared system prompt + random tails, alternating
    priorities, and a tight replay budget on every ``budget_every``-th
    request so preemption is reachable under heavy eviction."""
    sys_prompt = rng.integers(0, CFG.vocab, sys_pages * PAGE, dtype=np.int64)
    reqs = []
    for i in range(n_reqs):
        if sys_pages and rng.random() < 0.7:
            tail = rng.integers(0, CFG.vocab, int(rng.integers(1, 6)),
                                dtype=np.int64)
            p = np.concatenate([sys_prompt, tail])
        else:
            p = rng.integers(0, CFG.vocab, int(rng.integers(1, 11)),
                             dtype=np.int64)
        budget = None
        if budget_every and i % budget_every == budget_every - 1:
            budget = len(p) + max_new  # one cheap replay, not two
        reqs.append(Request(
            rid=i, prompt=np.asarray(p, np.int32), max_new=max_new,
            priority=priorities[i % len(priorities)], replay_budget=budget,
        ))
    return reqs


# ---------------------------------------------------------------------------
# The headline acceptance run: forced pool exhaustion across the full matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv", ["fp32", "int8"])
@pytest.mark.parametrize("sharing", [False, True])
def test_chaos_pool_pressure_matrix(kv, sharing):
    model = MODELS[kv]
    for seed in range(SEED_BASE, SEED_BASE + SEEDS_PER_CASE):
        rng = np.random.default_rng(seed)
        max_new = 4
        requests = _mk_requests(rng, n_reqs=4, max_new=max_new)
        worst = max(
            -(-(len(r.prompt) + max_new - 1) // PAGE) for r in requests
        )
        pool = worst + 2  # tight: organic contention on top of the faults

        def run(faults):
            cache = PagedKVCache.create(
                CFG, batch=2, max_len=MAX_LEN, page=PAGE,
                pool_pages=pool, kv_dtype=KV_DTYPE[kv],
            )
            reqs = [
                Request(rid=r.rid, prompt=r.prompt.copy(),
                        max_new=r.max_new, priority=r.priority,
                        replay_budget=r.replay_budget)
                for r in requests
            ]
            sched = Scheduler(model, cache, chunk=3, prefix_sharing=sharing,
                              faults=faults)
            out = chaos_drive(sched, reqs)
            return out, sched, reqs

        clean_out, clean_sched, _ = run(None)
        plan = FaultPlan.random(seed, n_steps=20, p_exhaust=0.35,
                                p_deny=0.2, p_drop=0.2)
        chaos_out, chaos_sched, chaos_reqs = run(plan)

        # Every request reached exactly one terminal state, zero crashes.
        states = terminal_states(chaos_reqs)
        assert set(states.values()) <= {"finished", "preempted"}
        # Non-preempted outputs are bit-for-bit the fault-free outputs.
        for rid, toks in chaos_out.items():
            assert toks == clean_out[rid], (
                f"seed {seed}: rid {rid} diverged under chaos"
            )
        # The fault-free leg finished everything (budgets are generous
        # without injected exhaustion).
        assert set(clean_out) == {r.rid for r in requests}
        # Drained pool is leak-free even after forced churn.
        chaos_sched.flush_prefix_cache()
        assert sorted(chaos_sched.cache.free) == list(range(pool))


# ---------------------------------------------------------------------------
# Targeted fault classes
# ---------------------------------------------------------------------------


def test_denied_allocation_defers_and_stays_consistent():
    """deny_alloc is the mid-flight OutOfPages scenario: growth must defer —
    never raise, never leave a partially-grown table behind."""
    model = MODELS["fp32"]
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, CFG.vocab, 4).astype(np.int32)
    plan = FaultPlan(seed=2, deny_alloc_at=frozenset(range(1, 10)))

    def run(faults):
        cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
        sched = Scheduler(model, cache, chunk=4, faults=faults)
        reqs = [Request(rid=0, prompt=prompt.copy(), max_new=8)]
        return chaos_drive(sched, reqs), sched

    clean, _ = run(None)
    chaos, sched = run(plan)
    assert chaos == clean
    # Denied steps really happened (the run outlasted the fault window).
    assert sched._step > 9


def test_forced_exhaustion_single_resident_self_evicts():
    """Pool exhaustion with one resident used to be a raise; now the request
    defers by self-eviction and replays bit-for-bit once the fault clears.

    A second queued request keeps lookahead prealloc off (lookahead only
    runs with an empty queue), so the resident grows on demand — step 3 is
    its first page-boundary growth, where the injected exhaustion lands."""
    model = MODELS["fp32"]
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, CFG.vocab, 4).astype(np.int32)
               for _ in range(2)]
    plan = FaultPlan(seed=3, exhaust_at=frozenset({2, 3}))

    def run(faults):
        cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
        sched = Scheduler(model, cache, chunk=4, faults=faults)
        reqs = [Request(rid=i, prompt=p.copy(), max_new=8)
                for i, p in enumerate(prompts)]
        return chaos_drive(sched, reqs), sched, reqs

    clean, _, _ = run(None)
    chaos, sched, reqs = run(plan)
    assert chaos == clean
    assert reqs[0].n_evictions >= 1  # it was actually pushed out mid-flight
    assert sched.stats.n_preempted == 0


def test_replay_budget_exhaustion_preempts_with_partial_output():
    model = MODELS["fp32"]
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, CFG.vocab, 4).astype(np.int32)
               for _ in range(2)]
    plan = FaultPlan(seed=4, exhaust_at=frozenset({2, 3}))
    cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
    sched = Scheduler(model, cache, chunk=4, faults=plan)
    req = Request(rid=0, prompt=prompts[0], max_new=8, replay_budget=0)
    other = Request(rid=1, prompt=prompts[1], max_new=8)
    out = chaos_drive(sched, [req, other])
    assert set(out) == {1}  # rid 0 never finished …
    assert req.state is RequestState.PREEMPTED
    assert sched.preempted[0] is req
    assert len(req.generated) >= 1  # … but its partial output survives
    assert sched.stats.n_preempted == 1
    assert sched.stats.n_evictions == 0  # budget burned on first eviction
    # Preemption released everything: pool back to pristine.
    assert sorted(sched.cache.free) == list(range(sched.cache.total_pages))


def test_preemption_picks_lowest_priority_victim():
    """Under growth pressure the victim is the lowest-priority resident —
    the old policy (youngest) would have evicted the late arrival."""
    model = MODELS["fp32"]
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, CFG.vocab, 4).astype(np.int32)
               for _ in range(2)]
    max_new = 12
    # Two residents, pool sized so decode growth contends.
    cache = PagedKVCache.create(CFG, batch=2, max_len=MAX_LEN, page=PAGE,
                                pool_pages=5)
    sched = Scheduler(model, cache, chunk=4)
    low = Request(rid=0, prompt=prompts[0], max_new=max_new, priority=0)
    high = Request(rid=1, prompt=prompts[1], max_new=max_new, priority=5)
    out = chaos_drive(sched, [low, high])
    assert low.n_evictions >= 1, "low-priority resident was never preempted"
    assert high.n_evictions == 0, "high-priority request lost its slot"
    # Replay keeps the evicted request's tokens bit-for-bit.
    ref_cache = PagedKVCache.create(CFG, batch=2, max_len=MAX_LEN, page=PAGE)
    ref = Scheduler(model, ref_cache, chunk=4)
    ref_out = chaos_drive(ref, [
        Request(rid=0, prompt=prompts[0].copy(), max_new=max_new),
        Request(rid=1, prompt=prompts[1].copy(), max_new=max_new),
    ])
    assert out == ref_out


def test_priority_orders_admission():
    model = MODELS["fp32"]
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, CFG.vocab, 4).astype(np.int32)
               for _ in range(2)]
    cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
    sched = Scheduler(model, cache, chunk=4)
    batchy = Request(rid=0, prompt=prompts[0], max_new=4, priority=0)
    urgent = Request(rid=1, prompt=prompts[1], max_new=4, priority=9)
    chaos_drive(sched, [batchy, urgent])  # submitted batchy first
    assert urgent.finish_step < batchy.finish_step


def test_queued_deadline_expiry_rejects_pool_busy():
    model = MODELS["fp32"]
    rng = np.random.default_rng(7)
    cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
    sched = Scheduler(model, cache, chunk=4)
    # Deadline ordering would serve the short request first, so the hog
    # outranks it by priority — the starvation the expiry path exists for.
    hog = Request(rid=0, prompt=rng.integers(0, CFG.vocab, 8).astype(np.int32),
                  max_new=12, priority=5)
    # Feasible at submit (min 2 steps ≤ 2), starved by the hog.
    late = Request(rid=1, prompt=rng.integers(0, CFG.vocab, 4)
                   .astype(np.int32), max_new=4, deadline_steps=2)
    out = chaos_drive(sched, [hog, late])
    assert set(out) == {0}
    assert late.state is RequestState.REJECTED
    assert late.reject_reason is RejectReason.POOL_BUSY
    assert sched.stats.reject_reasons == {"pool-busy": 1}
    assert sched.stats.deadline_misses == 1


def test_prefix_drop_fault_forces_reprefill_same_outputs():
    model = MODELS["fp32"]
    rng = np.random.default_rng(8)
    sys_prompt = rng.integers(0, CFG.vocab, 2 * PAGE)
    prompts = [
        np.concatenate([sys_prompt, rng.integers(0, CFG.vocab, t)])
        .astype(np.int32)
        for t in (2, 3, 4)
    ]
    plan = FaultPlan(seed=8, drop_prefix_at=frozenset(range(1, 12)))

    def run(faults):
        cache = PagedKVCache.create(CFG, batch=2, max_len=MAX_LEN, page=PAGE)
        sched = Scheduler(model, cache, chunk=4, prefix_sharing=True,
                          faults=faults)
        reqs = [Request(rid=i, prompt=p.copy(), max_new=3)
                for i, p in enumerate(prompts)]
        return chaos_drive(sched, reqs), sched

    clean, _ = run(None)
    chaos, sched = run(plan)
    assert chaos == clean
    assert sched.stats.n_prefix_drops >= 1


def test_prefix_drop_skips_counted_without_prefix_index():
    """drop_prefix against an engine with no prefix index (sharing off, or a
    recurrent family that has no token-granular units at all) must no-op
    with a counted skip — never raise, never change tokens."""
    model = MODELS["fp32"]
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, CFG.vocab, 6).astype(np.int32)
    plan = FaultPlan(seed=12, drop_prefix_at=frozenset(range(1, 8)))
    cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
    clean_cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
    clean = chaos_drive(Scheduler(model, clean_cache, chunk=4),
                        [Request(rid=0, prompt=prompt.copy(), max_new=6)])
    sched = Scheduler(model, cache, chunk=4, faults=plan)  # sharing off
    out = chaos_drive(sched, [Request(rid=0, prompt=prompt.copy(), max_new=6)])
    assert out == clean
    assert sched.stats.n_prefix_drop_skips >= 1
    assert sched.stats.n_prefix_drops == 0


def test_chaos_recurrent_family_seed_matrix():
    """The full random fault plan against a recurrent (RWKV6) scheduler:
    prefix-drop faults are family-inapplicable (counted skips), exhaustion
    and denial degrade via the same evict/defer ladder, and surviving
    outputs stay bit-for-bit the fault-free ones."""
    from repro.serve import RecurrentLM

    rcfg = smoke_config("rwkv6-3b")
    rmodel = RecurrentLM(rcfg, jax.random.PRNGKey(0), impl="ref")
    for seed in range(SEED_BASE, SEED_BASE + SEEDS_PER_CASE):
        rng = np.random.default_rng(1000 + seed)
        prompts = [rng.integers(0, rcfg.vocab, int(rng.integers(2, 10)))
                   .astype(np.int32) for _ in range(4)]

        def run(faults):
            sched = Scheduler(rmodel, rmodel.init_pool(2), chunk=3,
                              faults=faults)
            reqs = [Request(rid=i, prompt=p.copy(), max_new=4)
                    for i, p in enumerate(prompts)]
            return chaos_drive(sched, reqs), sched, reqs

        clean_out, _, _ = run(None)
        plan = FaultPlan.random(seed, n_steps=16, p_exhaust=0.3,
                                p_deny=0.2, p_drop=0.5)
        chaos_out, sched, reqs = run(plan)
        states = terminal_states(reqs)
        assert set(states.values()) <= {"finished", "preempted"}
        for rid, toks in chaos_out.items():
            assert toks == clean_out[rid], (
                f"seed {seed}: rid {rid} diverged under recurrent chaos"
            )
        assert set(clean_out) == {r.rid for r in reqs}
        # Inapplicable prefix drops were skipped, not raised (plan always
        # has drop steps at p_drop=0.5 over 16 steps for these seeds).
        if plan.drop_prefix_at:
            assert sched.stats.n_prefix_drop_skips >= 1
        # Drained state pool is leak-free.
        assert sched.family.free_units == 2


def test_injected_latency_trips_straggler_watchdog():
    model = MODELS["fp32"]
    rng = np.random.default_rng(9)
    # The whole run is 2 steps (prefill, prefill+fused decode): step 1 seeds
    # the EMA baseline, step 2 carries the injected pathological latency.
    plan = FaultPlan(seed=9, delay_at={2: 30.0})
    watchdog = StragglerWatchdog(FaultToleranceConfig(straggler_factor=3.0))
    cache = PagedKVCache.create(CFG, batch=1, max_len=MAX_LEN, page=PAGE)
    sched = Scheduler(model, cache, chunk=4, faults=plan, watchdog=watchdog)
    prompt = rng.integers(0, CFG.vocab, 8).astype(np.int32)
    out = chaos_drive(sched, [Request(rid=0, prompt=prompt, max_new=8)])
    assert len(out[0]) == 8
    assert watchdog.stragglers == 1
    assert sched.stats.n_stragglers == 1
    # Nobody actually slept: the injected 30 s is bookkeeping, not wall time.
    assert sum(watchdog.history) >= 30.0
    assert sched.stats.wall_s == 0.0  # chaos_drive steps manually


# ---------------------------------------------------------------------------
# The oracle itself, and the plan
# ---------------------------------------------------------------------------


def test_invariant_checker_fires_on_corruption():
    model = MODELS["fp32"]
    rng = np.random.default_rng(10)
    cache = PagedKVCache.create(CFG, batch=2, max_len=MAX_LEN, page=PAGE)
    sched = Scheduler(model, cache, chunk=4)
    # 8-token prompt at chunk=4: still mid-prefill (resident) after step 1.
    sched.submit(Request(rid=0, prompt=rng.integers(0, CFG.vocab, 8)
                         .astype(np.int32), max_new=4))
    sched.step()
    check_scheduler_invariants(sched)  # sane mid-flight
    sched._free_slots.append(sched.resident[0].slot)  # corrupt: slot double-owned
    with pytest.raises(InvariantViolation):
        check_scheduler_invariants(sched)


def test_fault_plan_is_deterministic_and_finite():
    a = FaultPlan.random(42, n_steps=24, p_delay=0.2)
    b = FaultPlan.random(42, n_steps=24, p_delay=0.2)
    assert a == b
    assert 0 <= a.horizon <= 24
    assert FaultPlan.none().horizon == 0
    # Probabilities actually bite at these intensities.
    assert a.exhaust_at and a.deny_alloc_at
