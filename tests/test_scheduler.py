"""Continuous-batching scheduler tests: page-pool admission control,
eviction/re-admission round-trips, and bit-for-bit equivalence between
scheduled continuous batching and a single static batch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import (
    IndirectStream,
    page_table_streams,
    paged_decode_traffic,
    paged_prefill_traffic,
    prefill_page_counts,
    prefill_table_streams,
)
from repro.kernels import ops, ref
from repro.serve import (
    OutOfPages,
    PagedKVCache,
    PagedLM,
    RejectReason,
    Request,
    RequestRejected,
    RequestState,
    Scheduler,
    SchedulerStalledError,
    static_batch_generate,
)

CFG = smoke_config("yi-6b")
MODEL = PagedLM(CFG, jax.random.PRNGKey(0), impl="ref")


def _prompts(rng, lens):
    return [rng.integers(0, CFG.vocab, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------


def test_admission_blocks_when_pool_full():
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, (8, 8))
    # Pool of 3 pages: each request peaks at 3 → only one resident at once.
    cache = PagedKVCache.create(CFG, batch=2, max_len=12, page=4, pool_pages=3)
    sched = Scheduler(MODEL, cache, chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=2) for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    sched.step()
    assert reqs[0].state in (RequestState.PREFILL, RequestState.RUNNING)
    assert reqs[1].state is RequestState.WAITING  # pool-full: not admitted
    out = sched.run()
    assert sorted(out) == [0, 1]
    assert all(len(t) == 2 for t in out.values())
    assert sched.cache.n_free == 3  # all pages returned


def test_submit_rejects_request_larger_than_pool():
    cache = PagedKVCache.create(CFG, batch=1, max_len=8, page=4, pool_pages=1)
    sched = Scheduler(MODEL, cache, chunk=4)
    req = Request(rid=0, prompt=np.zeros(8, np.int32), max_new=4)
    with pytest.raises(RequestRejected) as exc:
        sched.submit(req)
    # Typed, non-fatal rejection: the request is terminal, not lost, and the
    # scheduler stays usable.
    assert exc.value.reason is RejectReason.NEVER_FITS
    assert req.state is RequestState.REJECTED
    assert sched.rejected[0] is req
    assert sched.stats.n_rejected == 1
    # Non-strict submission reports rather than raises.
    req2 = Request(rid=1, prompt=np.zeros(8, np.int32), max_new=4)
    assert sched.submit(req2, strict=False) is False
    assert req2.reject_reason is RejectReason.NEVER_FITS


def test_submit_rejects_infeasible_deadline():
    cache = PagedKVCache.create(CFG, batch=2, max_len=16, page=4)
    sched = Scheduler(MODEL, cache, chunk=4)
    # 8-token prompt at chunk=4 needs 2 prefill steps + 1 decode boundary.
    req = Request(rid=0, prompt=np.zeros(8, np.int32), max_new=4,
                  deadline_steps=2)
    assert sched.submit(req, strict=False) is False
    assert req.reject_reason is RejectReason.DEADLINE_INFEASIBLE
    assert sched.stats.deadline_misses == 1
    # The same request with a feasible deadline is served.
    ok = Request(rid=1, prompt=np.zeros(8, np.int32), max_new=4,
                 deadline_steps=8)
    assert sched.submit(ok) is True
    sched.run()
    assert len(sched.finished[1].generated) == 4
    assert sched.stats.deadline_misses == 1  # met: no new miss


def test_stall_diagnostic_names_stuck_request():
    cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4)
    sched = Scheduler(MODEL, cache, chunk=4)
    sched.submit(Request(rid=7, prompt=np.zeros(16, np.int32), max_new=8))
    with pytest.raises(SchedulerStalledError) as exc:
        sched.run(max_steps=1)  # prefill alone needs 4 steps
    msg = str(exc.value)
    assert "request 7" in msg
    assert "queued" in msg and "pages free" in msg
    assert "prefill_pos=4/16" in msg


# ---------------------------------------------------------------------------
# Eviction / re-admission
# ---------------------------------------------------------------------------


def test_eviction_readmission_roundtrip():
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, (8, 7))
    max_new = 8

    cache_ref = PagedKVCache.create(CFG, batch=2, max_len=16, page=4)
    want = static_batch_generate(MODEL, cache_ref, prompts, max_new, chunk=4)

    # 6-page pool, both requests growing to 4 pages → mid-decode contention.
    cache = PagedKVCache.create(CFG, batch=2, max_len=16, page=4, pool_pages=6)
    sched = Scheduler(MODEL, cache, chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    got = sched.run()

    assert sched.stats.n_evictions >= 1
    assert max(r.n_evictions for r in reqs) >= 1
    assert got == {i: want[i] for i in want}  # eviction invisible in output
    assert sched.cache.n_free == 6


def test_eviction_prefers_youngest_and_self_defers():
    """When the page-needing request is itself the youngest resident, it
    defers rather than evicting an older (possibly nearly-done) request."""
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, (4, 4))
    max_new = 12

    cache_ref = PagedKVCache.create(CFG, batch=2, max_len=16, page=4)
    want = static_batch_generate(MODEL, cache_ref, prompts, max_new, chunk=4)

    # 5-page pool; both requests peak at 4 pages → the younger one must
    # yield when both cross the 8-token page boundary.
    cache = PagedKVCache.create(CFG, batch=2, max_len=16, page=4, pool_pages=5)
    sched = Scheduler(MODEL, cache, chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    got = sched.run()

    assert reqs[0].n_evictions == 0      # the elder is never preempted
    assert reqs[1].n_evictions >= 1      # the younger defers itself
    assert got == {i: want[i] for i in want}


def test_submit_rejects_nonpositive_max_new():
    cache = PagedKVCache.create(CFG, batch=1, max_len=8, page=4)
    sched = Scheduler(MODEL, cache, chunk=4)
    with pytest.raises(ValueError):
        sched.submit(Request(rid=0, prompt=np.zeros(4, np.int32), max_new=0))


# ---------------------------------------------------------------------------
# Scheduled continuous batching ≡ static batch (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_scheduled_equals_static_batch():
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, (5, 9, 12))
    max_new = 6

    cache_ref = PagedKVCache.create(CFG, batch=3, max_len=32, page=4)
    want = static_batch_generate(MODEL, cache_ref, prompts, max_new, chunk=4)

    # Tight pool staggers admission; chunked prefill interleaves with decode.
    cache = PagedKVCache.create(CFG, batch=3, max_len=32, page=4,
                                pool_pages=10)
    sched = Scheduler(MODEL, cache, chunk=4)
    streamed, finished = [], []
    for i, p in enumerate(prompts):
        sched.submit(Request(
            rid=i, prompt=p, max_new=max_new,
            on_token=lambda r, t: streamed.append((r.rid, t)),
            on_finish=lambda r: finished.append(r.rid),
        ))
    got = sched.run()

    assert got == {i: want[i] for i in want}  # bit-for-bit token equality
    assert sorted(finished) == [0, 1, 2]
    # Streaming hooks: every token exactly once, in generation order per rid.
    for i in range(3):
        assert [t for rid, t in streamed if rid == i] == got[i]
    # Traffic accounting: PACK strictly beats the padded BASE stream.
    assert 0.0 < sched.stats.pack_efficiency <= 1.0
    assert sched.stats.base_efficiency < sched.stats.pack_efficiency
    assert sched.stats.tokens == 3 * max_new


# ---------------------------------------------------------------------------
# Paged KV append op (the indirect write converter in serving)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_kv_append_writes_one_row_per_active_seq(impl):
    rng = np.random.default_rng(3)
    p_tot, page, kvh, d, b = 6, 4, 2, 16, 3
    kp = jnp.asarray(rng.normal(size=(p_tot, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(p_tot, page, kvh, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, kvh, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, kvh, d)), jnp.float32)
    table = jnp.asarray([[0, 1], [2, 3], [4, 5]], jnp.int32)
    lengths = jnp.asarray([5, 3, 0], jnp.int32)
    active = jnp.asarray([True, True, False])

    k2, v2, l2 = ops.paged_kv_append(kp, vp, kn, vn, table, lengths, active,
                                     impl=impl)
    np.testing.assert_array_equal(np.asarray(l2), [6, 4, 0])
    # seq 0 wrote to page 1 offset 1; seq 1 to page 2 offset 3; seq 2 nothing.
    np.testing.assert_allclose(np.asarray(k2[1, 1]), np.asarray(kn[0]))
    np.testing.assert_allclose(np.asarray(v2[2, 3]), np.asarray(vn[1]))
    expect = np.asarray(kp).copy()
    expect[1, 1] = np.asarray(kn[0])
    expect[2, 3] = np.asarray(kn[1])
    np.testing.assert_allclose(np.asarray(k2), expect)


def test_paged_kv_append_pallas_matches_ref():
    rng = np.random.default_rng(4)
    p_tot, page, kvh, d, b = 8, 4, 2, 16, 5
    kp = jnp.asarray(rng.normal(size=(p_tot, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(p_tot, page, kvh, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, kvh, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, kvh, d)), jnp.float32)
    table = jnp.asarray(rng.permutation(p_tot)[: b * 1].reshape(b, 1),
                        jnp.int32)
    lengths = jnp.asarray(rng.integers(0, page, b), jnp.int32)
    active = jnp.asarray([True, False, True, True, False])
    outs = [
        ops.paged_kv_append(kp, vp, kn, vn, table, lengths, active, impl=im)
        for im in ("ref", "pallas")
    ]
    for a, b_ in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


# ---------------------------------------------------------------------------
# Stream descriptors + traffic accounting
# ---------------------------------------------------------------------------


def test_page_table_streams_describe_mapped_pages():
    table = np.array([[3, 1, 0, 0], [2, 5, 7, 0], [0, 0, 0, 0]])
    lengths = np.array([5, 12, 0])  # page=4 → 2, 3, 0 pages
    streams = page_table_streams(table, lengths, page_size=4, token_bytes=256)
    assert len(streams) == 2
    assert all(isinstance(s, IndirectStream) for s in streams)
    np.testing.assert_array_equal(streams[0].indices, [3, 1])
    np.testing.assert_array_equal(streams[1].indices, [2, 5, 7])
    assert streams[0].elem_bits == 4 * 256 * 8


def test_prefill_table_streams_match_traffic_page_math():
    """The prefill stream descriptors (context read + chunk write per row)
    and ``paged_prefill_traffic`` must account exactly the same pages —
    one source of truth (``prefill_page_counts``) for descriptors, bytes,
    and the kernel's scalar-prefetch walk."""
    table = np.array([[3, 1, 6, 0], [2, 5, 7, 4], [9, 8, 0, 0]])
    starts = np.array([0, 5, 0])
    counts = np.array([4, 6, 0])    # page=4: ctx 1|3|0, chunk 1|2|0 pages
    streams = prefill_table_streams(
        table, starts, counts, page_size=4, token_bytes=256
    )
    assert len(streams) == 4        # two per real row, none for padding
    assert all(isinstance(s, IndirectStream) for s in streams)
    np.testing.assert_array_equal(streams[0].indices, [3])        # row0 ctx
    np.testing.assert_array_equal(streams[1].indices, [3])        # row0 chunk
    np.testing.assert_array_equal(streams[2].indices, [2, 5, 7])  # row1 ctx
    np.testing.assert_array_equal(streams[3].indices, [5, 7])     # row1 chunk
    ctx, chunk = prefill_page_counts(starts, counts, 4)
    assert sum(s.count for s in streams) == int(ctx.sum() + chunk.sum())
    t = paged_prefill_traffic(
        starts, counts, page_size=4, pages_per_seq=4, token_bytes=256
    )
    page_bytes = 4 * 256
    assert t.pack_bytes == int(ctx.sum() + chunk.sum()) * page_bytes


def test_scheduler_prefill_records_carry_streams():
    """Prefill StepRecords expose their indirect-stream descriptors (as
    decode records already do), and the stats aggregate the prefill-side
    PACK/BASE traffic separately."""
    rng = np.random.default_rng(21)
    prompts = _prompts(rng, (9, 5))
    cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4)
    sched = Scheduler(MODEL, cache, chunk=4)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=3))
    sched.run()
    prefills = [r for r in sched.stats.records if r.kind == "prefill"]
    assert prefills and all(r.streams for r in prefills)
    assert all(isinstance(s, IndirectStream)
               for r in prefills for s in r.streams)
    # Stream pages == traffic pages, step by step.
    page_bytes = 4 * MODEL.kv_token_bytes
    for r in prefills:
        assert sum(s.count for s in r.streams) * page_bytes \
            == r.traffic.pack_bytes
    assert sched.stats.prefill_steps == len(prefills)
    assert 0.0 < sched.stats.prefill_pack_efficiency <= 1.0
    assert sched.stats.prefill_pack_efficiency \
        > sched.stats.prefill_base_efficiency


def test_paged_decode_traffic_base_vs_pack():
    t = paged_decode_traffic(
        lengths=[5, 12], page_size=4, pages_per_seq=4, token_bytes=256
    )
    assert t.useful_bytes == 17 * 256
    assert t.base_bytes == 2 * 4 * 4 * 256          # padded contiguous cache
    assert t.pack_bytes == 5 * 4 * 256              # 5 mapped pages
    assert t.index_bus_bytes_base == 0              # BASE has no indices
    assert t.index_bus_bytes_pack == 32             # 5 ids, granule-rounded
    assert t.pack_efficiency > t.base_efficiency


# ---------------------------------------------------------------------------
# Cache bookkeeping under mid-flight entry/exit
# ---------------------------------------------------------------------------


def test_paged_cache_midflight_extend_and_release():
    cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4,
                                pool_pages=8)
    cache = cache.allocate(0, 2)
    cache = cache.allocate(1, 3)
    assert cache.n_free == 3
    cache = cache.allocate(0, 1)  # mid-flight growth appends, not overwrites
    table = np.asarray(cache.page_table)
    assert len(set(table[0, :3].tolist())) == 3
    assert cache.n_free == 2
    with pytest.raises(OutOfPages):
        cache.allocate(0, 3)
    cache = cache.release(1)
    assert cache.n_free == 5
    assert int(np.asarray(cache.lengths)[1]) == 0
    cache = cache.release(0)
    assert sorted(cache.free) == list(range(8))
