"""Property tests for the numeric core: chunked ops ≡ dense references.

These invariants are what make the memory-discipline machinery safe: every
chunked/streamed formulation must be exactly the math of its dense form.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.models.common import (
    apply_rope,
    chunked_mha,
    chunked_softmax_xent,
    decayed_cumsum,
    rms_norm,
)


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([2, 4]),
    kvh=st.sampled_from([1, 2]),
    chunk=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_mha_equals_dense(s, h, kvh, chunk, causal, seed):
    if h % kvh:
        kvh = 1
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(2, s, h, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, s, kvh, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, s, kvh, 16)), jnp.float32)
    out = chunked_mha(q, k, v, causal=causal, kv_chunk=chunk)
    # dense reference expects (B,H,S,D)
    expect = ref.mha(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([8, 32, 64]),
    chunk=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decayed_cumsum_equals_sequential(t, chunk, seed):
    """h_t = a_t h_{t-1} + b_t — chunked assoc-scan vs naive loop."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0.2, 1.0, size=(t, 4, 3)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(t, 4, 3)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    if t % chunk:
        chunk = t
    hs, h_last = decayed_cumsum(a, b, h0, chunk=chunk)
    h = np.asarray(h0)
    seq = []
    for i in range(t):
        h = np.asarray(a)[i] * h + np.asarray(b)[i]
        seq.append(h.copy())
    np.testing.assert_allclose(np.asarray(hs), np.stack(seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), seq[-1], rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    s=st.sampled_from([8, 16, 64]),
    v=st.sampled_from([32, 100]),
    chunk=st.sampled_from([4, 8, 16]),
    pad=st.sampled_from([0, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_xent_equals_dense(s, v, chunk, pad, seed):
    rng = np.random.default_rng(seed)
    if s % chunk:
        chunk = s
    x = jnp.asarray(rng.normal(size=(2, s, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, v + pad)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (2, s)))
    mask = jnp.asarray(rng.random((2, s)) > 0.3, jnp.float32)
    loss, cnt = chunked_softmax_xent(x, w, labels, mask, seq_chunk=chunk, n_valid=v)
    # dense reference (mask padded classes)
    logits = np.asarray(x) @ np.asarray(w)
    logits[..., v:] = -1e30
    logits = logits - logits.max(-1, keepdims=True)
    logp = logits - np.log(np.exp(logits).sum(-1, keepdims=True))
    nll = -np.take_along_axis(logp, np.asarray(labels)[..., None], -1)[..., 0]
    m = np.asarray(mask)
    expect = (nll * m).sum() / max(m.sum(), 1)
    np.testing.assert_allclose(float(loss), expect, rtol=1e-4, atol=1e-5)
    assert float(cnt) == m.sum()


@settings(max_examples=15, deadline=None)
@given(offset=st.integers(0, 64), seed=st.integers(0, 2**31 - 1))
def test_rope_relative_position_invariance(offset, seed):
    """RoPE property: <rope(q,i), rope(k,j)> depends only on i-j."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)

    def dot_at(i, j):
        qr = apply_rope(q, jnp.asarray([i]), 1e4)
        kr = apply_rope(k, jnp.asarray([j]), 1e4)
        return float(jnp.sum(qr * kr))

    d1 = dot_at(3, 1)
    d2 = dot_at(3 + offset, 1 + offset)
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    scale=st.floats(0.1, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rms_norm_scale_invariance(scale, seed):
    """rms_norm(c·x) == rms_norm(x) for any positive c (f32)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 8, 32)) + 0.1, jnp.float32)
    g = jnp.asarray(rng.normal(size=(32,)) * 0.1, jnp.float32)
    a = rms_norm(x, g)
    b = rms_norm(x * scale, g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_quantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
    q, scale = ref.int8_quantize(x)
    back = ref.int8_dequantize(q, scale)
    # error bounded by half an LSB of the per-row scale
    bound = np.asarray(scale)[:, 0] / 2 + 1e-7
    err = np.abs(np.asarray(back) - np.asarray(x)).max(axis=1)
    assert (err <= bound + 1e-6).all()
