"""Assertions tying the reproduction to the paper's measured claims
(EXPERIMENTS.md §Paper-reproduction table)."""
import numpy as np
import pytest

from benchmarks.paper_workloads import (
    evaluate, fig3a_rows, gemv_model, ismt_model, spmv_model, synth_csr,
    trmv_model,
)
from benchmarks.fig3_scaling import fig3d_ismt_scaling, fig3e_spmv_scaling
from repro.core import System


@pytest.fixture(scope="module")
def rows():
    return {r.name: r for r in fig3a_rows(n=256, sparse_rows=96, avg_nnz=390)}


def test_ismt_speedup_matches_paper(rows):
    assert rows["ismt"].speedup_pack == pytest.approx(5.4, rel=0.15)


def test_gemv_utilization_matches_paper(rows):
    assert rows["gemv-col"].util_pack == pytest.approx(0.87, abs=0.03)


def test_gemv_row_base_utilization():
    util = gemv_model(256, "row").evaluate(System.BASE).bus_util
    assert util == pytest.approx(0.37, abs=0.03)


def test_trmv_utilization_matches_paper(rows):
    assert rows["trmv-col"].util_pack == pytest.approx(0.72, abs=0.08)


def test_spmv_speedup_matches_paper(rows):
    assert rows["spmv"].speedup_pack == pytest.approx(2.4, rel=0.25)


def test_sssp_speedup_and_ordering(rows):
    """Model utilization for indirect workloads is documented-high (~58 %,
    full mem/compute overlap vs Ara's measured 35-39 % with issue stalls);
    the invariants tested: sssp ≥ spmv utilization (paper ordering) and
    speedup in the paper's indirect band."""
    assert rows["sssp"].util_pack >= rows["spmv"].util_pack - 0.01
    assert 1.8 <= rows["sssp"].speedup_pack <= 3.5
    # and all indirect utils respect the r/(r+1)=50 % bus ceiling + overlap
    assert rows["sssp"].util_pack < 0.67


def test_pack_close_to_ideal(rows):
    """Paper: PACK reaches 97 % of IDEAL on average."""
    fracs = [r.pack_vs_ideal for r in rows.values()]
    assert np.mean(fracs) > 0.9


def test_fig3d_bus_width_convergence():
    """ismt speedups converge to ≈1.9 / 3.2 / 5.4 for 64/128/256-bit buses."""
    rows = fig3d_ismt_scaling(sizes=(256,), widths=(64, 128, 256))
    got = {r["bus_bits"]: r["speedup"] for r in rows}
    assert got[64] == pytest.approx(1.9, rel=0.15)
    assert got[128] == pytest.approx(3.2, rel=0.15)
    assert got[256] == pytest.approx(5.4, rel=0.15)


def test_fig3d_small_matrices_lose_speedup():
    rows = fig3d_ismt_scaling(sizes=(8, 256), widths=(256,))
    small = next(r for r in rows if r["n"] == 8)["speedup"]
    big = next(r for r in rows if r["n"] == 256)["speedup"]
    assert small < big
    assert small >= 1.0  # request bundling: never a slowdown


def test_fig3e_nnz_scaling():
    rows = fig3e_spmv_scaling(nnz_list=(2, 390), widths=(256,), n_rows=48)
    small = next(r for r in rows if r["avg_nnz"] == 2)["speedup"]
    big = next(r for r in rows if r["avg_nnz"] == 390)["speedup"]
    assert small < big
    assert small >= 1.0
    assert big == pytest.approx(2.4, rel=0.3)


# ---------------------------------------------------------------------------
# Executable workload implementations agree with numpy ground truth
# ---------------------------------------------------------------------------


def test_workload_impls_correct():
    import jax.numpy as jnp
    from benchmarks import workload_impls as W
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 64)).astype(np.float32)
    x = rng.normal(size=(64,)).astype(np.float32)

    out, _ = W.ismt(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(out), a.T)

    y, _ = W.gemv_col(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), a @ x, rtol=1e-4)

    y, _ = W.trmv(jnp.asarray(a), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), np.triu(a) @ x, rtol=1e-4)

    # spmv / pagerank on a synthetic ELL matrix
    indptr, indices, data = synth_csr(48, 6, n_cols=48, seed=3)
    vals, cols = ref.csr_to_ell(indptr, indices, data, 48)
    dense = np.zeros((48, 48), np.float32)
    for r in range(48):
        dense[r, indices[indptr[r]:indptr[r+1]]] = data[indptr[r]:indptr[r+1]]
    y, _ = W.spmv(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x[:48]))
    np.testing.assert_allclose(np.asarray(y), dense @ x[:48], rtol=1e-4, atol=1e-4)

    # pagerank converges to a distribution on a well-posed stochastic matrix
    adj = (np.abs(dense) > 0).astype(np.float32) + np.eye(48, dtype=np.float32)
    col_sum = adj.sum(0, keepdims=True)
    pvals = adj / col_sum                     # column-stochastic
    pv, pc = ref.csr_to_ell(*_to_csr(pvals), 48)
    r, _ = W.pagerank(jnp.asarray(pv), jnp.asarray(pc), 48, iters=50)
    r = np.asarray(r)
    assert np.all(r > 0)
    np.testing.assert_allclose(r.sum(), 1.0, atol=0.05)

    # sssp: distances decrease monotonically and src = 0
    mask = vals != 0
    wv = np.abs(vals) + mask * 0.1
    d, _ = W.sssp(jnp.asarray(wv), jnp.asarray(cols), jnp.asarray(mask),
                  src=0, n=48, iters=8)
    d = np.asarray(d)
    assert d[0] == 0.0
    assert np.isfinite(d).sum() >= 1


def _to_csr(dense):
    indptr = [0]
    indices, data = [], []
    for r in range(dense.shape[0]):
        nz = np.nonzero(dense[r])[0]
        indices.extend(nz.tolist())
        data.extend(dense[r, nz].tolist())
        indptr.append(len(indices))
    return (np.asarray(indptr), np.asarray(indices, np.int32),
            np.asarray(data, np.float32))
