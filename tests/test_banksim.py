"""Deterministic tests for the banked-endpoint simulator (core/banksim.py).

The conflict counts below are hand-derived from the crossbar's arbitration
rules (one grant per bank per cycle, words issued round-robin across
``n_ports`` lanes, one packed beat retired per cycle) on streams small
enough to trace by hand, then pinned exactly.  The serving-side replay
regression feeds the scheduler's own page-table stream descriptors —
including the prefix-sharing ``remap_only`` kind — through the simulator
and pins their cycle counts, so the accounting path from scheduler records
to Fig.-5-style endpoint numbers cannot silently drift.
"""
import math

import numpy as np
import pytest

from repro.core.banksim import (
    BankConfig,
    crossbar_area_kge,
    indirect_utilization,
    simulate_stream,
    simulate_words,
    strided_utilization,
)
from repro.core.streams import (
    IndirectStream,
    StridedStream,
    page_table_streams,
    share_table_streams,
)


# ---------------------------------------------------------------------------
# simulate_words: hand-computed conflict counts
# ---------------------------------------------------------------------------

def test_unit_stride_is_conflict_free():
    """8 consecutive words over 2 ports / 2 banks alternate banks perfectly:
    each cycle both lanes hit different banks, so every beat needs exactly
    one fetch cycle + pipelining — utilization 1.0, zero stalls."""
    cfg = BankConfig(n_ports=2, n_banks=2)
    r = simulate_words(np.arange(8, dtype=np.int64), cfg)
    assert r.data_beats == 4
    assert r.utilization == 1.0
    assert r.stall_cycles == 0


def test_stride_two_aliases_to_one_bank():
    """Words 0,2,4,6 all land in bank 0 (addr % 2 == 0): the two lanes
    serialize on the single bank, so each 2-word beat takes 2 fetch cycles —
    exactly half utilization."""
    cfg = BankConfig(n_ports=2, n_banks=2)
    r = simulate_words(np.array([0, 2, 4, 6], dtype=np.int64), cfg)
    assert r == type(r)(cycles=4, data_beats=2, utilization=0.5,
                        stall_cycles=2)


def test_ideal_flag_ignores_conflicts():
    cfg = BankConfig(n_ports=2, n_banks=2, ideal=True)
    r = simulate_words(np.array([0, 2, 4, 6], dtype=np.int64), cfg)
    assert r.cycles == 2 and r.utilization == 1.0 and r.stall_cycles == 0


def test_beats_round_up_to_port_width():
    """5 words over 4 ports = 2 beats (the last beat is partial)."""
    cfg = BankConfig(n_ports=4, n_banks=5)
    r = simulate_words(np.arange(5, dtype=np.int64), cfg)
    assert r.data_beats == math.ceil(5 / 4) == 2


# ---------------------------------------------------------------------------
# simulate_stream: descriptor-level behaviour (§III-E shapes)
# ---------------------------------------------------------------------------

def test_strided_prime_banks_beat_power_of_two():
    """stride-4 words alias mod 16 but sweep all residues mod 17: the prime
    endpoint is conflict-free while the pow2 one halves its throughput."""
    s = StridedStream(base=0, elem_bits=32, count=64, stride=4)
    r17 = simulate_stream(s, BankConfig(n_ports=8, n_banks=17))
    r16 = simulate_stream(s, BankConfig(n_ports=8, n_banks=16))
    assert r17.utilization == 1.0 and r17.stall_cycles == 0
    assert r16 == type(r16)(cycles=16, data_beats=8, utilization=0.5,
                            stall_cycles=8)


def test_strided_utilization_sensitivity():
    """Fig. 5b ordering on the worst-case power-of-two stride."""
    u16 = strided_utilization(8, BankConfig(n_ports=8, n_banks=16))
    u17 = strided_utilization(8, BankConfig(n_ports=8, n_banks=17))
    assert u16 == 0.25
    assert u17 == 1.0


def test_indirect_index_stage_shares_ports():
    """16 one-word elements = 2 data beats, but the 32-bit index line for
    each 8-element group must drain through the same ports first: the
    index/element round-robin costs the r/(r+1) ceiling of §III-B — here
    the measured schedule is 4 cycles for 2 beats."""
    idx = np.arange(16)[::-1].copy()
    s = IndirectStream(base=0, elem_bits=32, count=16, indices=idx,
                       index_bits=32)
    r = simulate_stream(s, BankConfig(n_ports=8, n_banks=17))
    assert r == type(r)(cycles=4, data_beats=2, utilization=0.5,
                        stall_cycles=2)


def test_indirect_utilization_below_index_ceiling():
    """Random 32-bit-index / 32-bit-element bursts can never beat r/(r+1)
    with r = 1 (one element word per index word): utilization ≤ 1/2, and a
    prime bank count shows no inherent advantage (§III-E)."""
    for banks in (16, 17):
        u = indirect_utilization(BankConfig(n_ports=8, n_banks=banks))
        assert 0.0 < u <= 0.5 + 1e-9


# ---------------------------------------------------------------------------
# Serving replay: scheduler descriptors through the endpoint
# ---------------------------------------------------------------------------

def test_page_table_streams_replay_pinned():
    """The paged-KV gather descriptors (one indirect stream per active
    sequence, elem_bits = one page's packed bytes) replay through the
    simulator with pinned cycle counts: a 3-page walk costs 25 cycles
    (24 data beats + 1 index stall) at the 8×17 endpoint."""
    table = np.array([[3, 1, 2, 0], [5, 4, 0, 0]])
    lengths = np.array([10, 6])
    streams = page_table_streams(table, lengths, page_size=4, token_bytes=64)
    assert len(streams) == 2
    cfg = BankConfig(n_ports=8, n_banks=17)
    r0, r1 = (simulate_stream(s, cfg) for s in streams)
    assert (r0.cycles, r0.data_beats, r0.stall_cycles) == (25, 24, 1)
    assert (r1.cycles, r1.data_beats, r1.stall_cycles) == (17, 16, 1)
    assert r0.utilization == pytest.approx(24 / 25)


def test_share_table_streams_remap_only_replay():
    """Prefix-sharing remap descriptors move no KV payload: only the table
    entries (one 32-bit index per shared page) cross the endpoint, so a
    3-page share drains in a single cycle — the dedup multiplier the
    accounting claims is really there at the endpoint."""
    (s,) = share_table_streams([3, 1, 2], page_size=4, token_bytes=64)
    assert s.remap_only and s.count == 3
    r = simulate_stream(s, BankConfig(n_ports=8, n_banks=17))
    assert r == type(r)(cycles=1, data_beats=1, utilization=1.0,
                        stall_cycles=0)
    # The equivalent *copy* would have drained the full page payload:
    full = simulate_stream(
        page_table_streams(
            np.array([[3, 1, 2, 0]]), np.array([12]),
            page_size=4, token_bytes=64,
        )[0],
        BankConfig(n_ports=8, n_banks=17),
    )
    assert full.data_beats > r.data_beats * 8  # >8× fewer beats via remap

    assert share_table_streams([], page_size=4, token_bytes=64) == ()


# ---------------------------------------------------------------------------
# Area model sanity
# ---------------------------------------------------------------------------

def test_crossbar_area_prime_overhead_shrinks():
    """Prime bank counts pay a fixed mod/div cost per port, so the relative
    overhead over the neighbouring pow2 design shrinks with bank count."""
    over_16 = crossbar_area_kge(8, 17) / crossbar_area_kge(8, 16)
    over_32 = crossbar_area_kge(8, 37) / crossbar_area_kge(8, 32)
    assert over_16 > over_32 > 1.0
    assert crossbar_area_kge(8, 16) == pytest.approx(55.0)
