"""Device-resident decode fast path: fused multi-step decode bitwise equals
the step-at-a-time path, batched prefill equals sequential chunks, the
bounded chunk-write op matches its oracle, the length-adaptive kernel stays
correct on ragged batches, and the hot path never recompiles in steady
state."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels import ops
from repro.serve import (
    PagedKVCache,
    PagedLM,
    Request,
    RequestState,
    Scheduler,
    static_batch_generate,
)

CFG = smoke_config("yi-6b")
MODEL = PagedLM(CFG, jax.random.PRNGKey(0), impl="ref")


def _prefilled(model, prompts, max_new, page=4, max_len=32):
    """Build a cache with every prompt prefilled (same bits every call)."""
    cache = PagedKVCache.create(
        CFG, batch=len(prompts), max_len=max_len, page=page
    )
    last = None
    for i, prompt in enumerate(prompts):
        cache = cache.allocate(i, cache.pages_for(len(prompt) + max_new))
        for start in range(0, len(prompt), 4):
            count = min(4, len(prompt) - start)
            buf = np.zeros((4,), np.int32)
            buf[:count] = prompt[start:start + count]
            logits, cache = model.prefill_chunk(
                jnp.asarray(buf), count, i, start, cache
            )
            last = logits
    return cache, last


def _prompts(rng, lens):
    return [rng.integers(0, CFG.vocab, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Fused decode ≡ sequential decode (the tentpole equivalence)
# ---------------------------------------------------------------------------


def test_decode_steps_bitwise_equals_sequential_decode_step():
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, (5, 7))
    n = 4

    cache_a, _ = _prefilled(MODEL, prompts, n + 1)
    cache_b, _ = _prefilled(MODEL, prompts, n + 1)
    tokens = np.asarray([3, 11], np.int32)
    active = np.asarray([True, True])

    # Sequential: n × decode_step with host-side argmax feeding back.
    seq_toks = []
    feed = tokens
    for _ in range(n):
        logits, cache_a = MODEL.decode_step(
            jnp.asarray(feed), cache_a, jnp.asarray(active)
        )
        feed = np.argmax(
            np.asarray(logits)[:, : CFG.vocab], axis=-1
        ).astype(np.int32)
        seq_toks.append(feed.copy())

    # Fused: one decode_steps launch with device-side argmax.
    fused, cache_b = MODEL.decode_steps(tokens, cache_b, active, n)
    np.testing.assert_array_equal(np.asarray(fused), np.stack(seq_toks))
    # Cache state (lengths + host shadow) advanced identically.
    np.testing.assert_array_equal(
        np.asarray(cache_a.lengths), np.asarray(cache_b.lengths)
    )
    np.testing.assert_array_equal(cache_a.lengths_host, cache_b.lengths_host)
    np.testing.assert_allclose(
        np.asarray(cache_a.k_pages), np.asarray(cache_b.k_pages)
    )


def test_decode_steps_inactive_slots_untouched():
    rng = np.random.default_rng(1)
    prompts = _prompts(rng, (6, 6))
    cache, _ = _prefilled(MODEL, prompts, 4)
    before = np.asarray(cache.lengths).copy()
    active = np.asarray([True, False])
    toks, cache = MODEL.decode_steps(
        np.asarray([1, 2], np.int32), cache, active, 3
    )
    after = np.asarray(cache.lengths)
    assert after[0] == before[0] + 3
    assert after[1] == before[1]          # inactive slot appended nothing
    np.testing.assert_array_equal(cache.lengths_host, after)


# ---------------------------------------------------------------------------
# Batched prefill ≡ sequential single-sequence chunks
# ---------------------------------------------------------------------------


def test_prefill_batch_bitwise_equals_sequential_chunks():
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, (6, 3, 9))
    cache_a = PagedKVCache.create(CFG, batch=3, max_len=32, page=4)
    cache_b = PagedKVCache.create(CFG, batch=3, max_len=32, page=4)
    for i, p in enumerate(prompts):
        cache_a = cache_a.allocate(i, cache_a.pages_for(len(p)))
        cache_b = cache_b.allocate(i, cache_b.pages_for(len(p)))

    chunk = 4
    # Sequential: one prefill_chunk per sequence per chunk position.
    logits_a = {}
    for i, p in enumerate(prompts):
        for start in range(0, len(p), chunk):
            count = min(chunk, len(p) - start)
            buf = np.zeros((chunk,), np.int32)
            buf[:count] = p[start:start + count]
            lg, cache_a = MODEL.prefill_chunk(
                jnp.asarray(buf), count, i, start, cache_a
            )
            logits_a[i] = np.asarray(lg)

    # Batched: all sequences advance one chunk per call (padding rows once a
    # short prompt is done).
    logits_b = {}
    maxlen = max(len(p) for p in prompts)
    for start in range(0, maxlen, chunk):
        toks = np.zeros((3, chunk), np.int32)
        counts = np.zeros((3,), np.int32)
        slots = np.arange(3, dtype=np.int32)
        starts = np.full((3,), start, np.int32)
        for i, p in enumerate(prompts):
            count = max(0, min(chunk, len(p) - start))
            toks[i, :count] = p[start:start + count]
            counts[i] = count
        lg, cache_b = MODEL.prefill_batch(toks, counts, slots, starts, cache_b)
        lg = np.asarray(lg)
        for i, p in enumerate(prompts):
            if counts[i] and start + counts[i] == len(p):
                logits_b[i] = lg[i]

    for i in range(3):
        np.testing.assert_array_equal(logits_a[i], logits_b[i])
    np.testing.assert_array_equal(
        np.asarray(cache_a.k_pages), np.asarray(cache_b.k_pages)
    )
    np.testing.assert_array_equal(
        np.asarray(cache_a.lengths), np.asarray(cache_b.lengths)
    )


def test_prefill_batch_padding_rows_never_nan():
    """counts==0 padding rows are fully masked under the bounded-context
    mask; the finite mask constant (not ``-inf``) keeps their softmax
    NaN-free, so padding can never poison the donated pools.  The padding
    slot's pool pages and the real rows' logits must be untouched."""
    rng = np.random.default_rng(10)
    prompts = _prompts(rng, (6,))
    cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4)
    cache = cache.allocate(0, cache.pages_for(6))
    toks = np.zeros((2, 4), np.int32)
    toks[0] = prompts[0][:4]
    logits, cache = MODEL.prefill_batch(
        toks, np.asarray([4, 0], np.int32), np.asarray([0, 1], np.int32),
        np.asarray([0, 0], np.int32), cache,
    )
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(np.asarray(cache.k_pages)).all()
    assert np.isfinite(np.asarray(cache.v_pages)).all()
    assert int(np.asarray(cache.lengths)[1]) == 0
    # Bitwise identical to the same prefill without the padding row.
    cache_b = PagedKVCache.create(CFG, batch=2, max_len=32, page=4)
    cache_b = cache_b.allocate(0, cache_b.pages_for(6))
    lg, cache_b = MODEL.prefill_batch(
        toks[:1], np.asarray([4], np.int32), np.asarray([0], np.int32),
        np.asarray([0], np.int32), cache_b,
    )
    np.testing.assert_array_equal(np.asarray(logits)[0], np.asarray(lg)[0])
    np.testing.assert_array_equal(
        np.asarray(cache.k_pages), np.asarray(cache_b.k_pages)
    )


def test_prefill_cache_is_lru_bounded():
    """Ragged (page, ctx) traffic mints jitted prefill programs; the cache
    must never exceed its cap, evicting least-recently-used buckets (an
    evicted bucket re-jits on demand — correctness never depends on
    residency)."""
    model = PagedLM(CFG, jax.random.PRNGKey(2), impl="ref",
                    prefill_cache_cap=3)
    rng = np.random.default_rng(11)
    keys_seen = []
    for page in (1, 2, 4, 8, 16):
        prompt = rng.integers(0, CFG.vocab, 8).astype(np.int32)
        cache = PagedKVCache.create(CFG, batch=1, max_len=16, page=page)
        cache = cache.allocate(0, cache.pages_for(len(prompt)))
        for start in range(0, 8, 4):
            _, cache = model.prefill_chunk(
                jnp.asarray(prompt[start:start + 4]), 4, 0, start, cache
            )
        keys_seen.extend(k for k in model._prefill_cache
                         if k not in keys_seen)
        assert len(model._prefill_cache) <= 3
    assert len(keys_seen) > 3                      # sweep really minted > cap
    # LRU order: the most recent buckets survive, the oldest were evicted.
    assert list(model._prefill_cache) == keys_seen[-3:]
    # An evicted bucket still works (recompiles transparently).
    page = 1
    prompt = rng.integers(0, CFG.vocab, 4).astype(np.int32)
    cache = PagedKVCache.create(CFG, batch=1, max_len=16, page=page)
    cache = cache.allocate(0, cache.pages_for(4))
    logits, _ = model.prefill_chunk(jnp.asarray(prompt), 4, 0, 0, cache)
    assert np.isfinite(np.asarray(logits)).all()
    assert len(model._prefill_cache) == 3


@pytest.mark.parametrize("lens", [
    (4, 8),          # exact page multiples (page=4)
    (16,),           # exactly fills ctx_pages (max_len)
    (12, 3, 16),     # page-multiple, sub-page, and full-table mixed
])
def test_prefill_boundary_lengths_match_sequential(lens):
    """Prompts ending exactly on page boundaries / exactly filling the
    page-table row: the pow2 ctx bucketing must cover the final page
    (the off-by-one spot) and stay bitwise equal to sequential chunks."""
    rng = np.random.default_rng(12)
    prompts = _prompts(rng, lens)
    b = len(prompts)
    cache_a = PagedKVCache.create(CFG, batch=b, max_len=16, page=4)
    cache_b = PagedKVCache.create(CFG, batch=b, max_len=16, page=4)
    for i, p in enumerate(prompts):
        cache_a = cache_a.allocate(i, cache_a.pages_for(len(p)))
        cache_b = cache_b.allocate(i, cache_b.pages_for(len(p)))
    chunk = 4
    logits_a = {}
    for i, p in enumerate(prompts):
        for start in range(0, len(p), chunk):
            count = min(chunk, len(p) - start)
            buf = np.zeros((chunk,), np.int32)
            buf[:count] = p[start:start + count]
            lg, cache_a = MODEL.prefill_chunk(
                jnp.asarray(buf), count, i, start, cache_a
            )
            logits_a[i] = np.asarray(lg)
    logits_b = {}
    for start in range(0, max(lens), chunk):
        toks = np.zeros((b, chunk), np.int32)
        counts = np.zeros((b,), np.int32)
        for i, p in enumerate(prompts):
            count = max(0, min(chunk, len(p) - start))
            toks[i, :count] = p[start:start + count]
            counts[i] = count
        lg, cache_b = MODEL.prefill_batch(
            toks, counts, np.arange(b, dtype=np.int32),
            np.full((b,), start, np.int32), cache_b,
        )
        for i, p in enumerate(prompts):
            if counts[i] and start + counts[i] == len(p):
                logits_b[i] = np.asarray(lg)[i]
    for i in range(b):
        np.testing.assert_array_equal(logits_a[i], logits_b[i])
    np.testing.assert_array_equal(
        np.asarray(cache_a.k_pages), np.asarray(cache_b.k_pages)
    )
    np.testing.assert_array_equal(
        np.asarray(cache_a.lengths), np.asarray(cache_b.lengths)
    )


# ---------------------------------------------------------------------------
# Bounded chunk write op vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("starts,counts", [
    ([0, 5, 14], [6, 0, 2]),       # page-straddling, padding row, tail write
    ([3, 0, 7], [1, 6, 6]),        # single row, full chunk, cross-page
])
def test_paged_kv_write_chunk_pallas_matches_ref(starts, counts):
    rng = np.random.default_rng(3)
    pool, page, kvh, d, b, npg, c = 16, 4, 2, 16, 3, 4, 6
    kp = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, c, kvh, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, c, kvh, d)), jnp.float32)
    rows = jnp.asarray(rng.permutation(pool)[: b * npg].reshape(b, npg),
                       jnp.int32)
    st = jnp.asarray(starts, jnp.int32)
    ct = jnp.asarray(counts, jnp.int32)
    outs = [
        ops.paged_kv_write_chunk(kp, vp, kn, vn, rows, st, ct, impl=im)
        for im in ("ref", "pallas")
    ]
    for a, b_ in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_paged_kv_write_chunk_never_clobbers_other_pages():
    """A stale copy of an untouched window page must not overwrite another
    sequence's write to that same physical page (the scatter-back of junk
    window slots is routed out of bounds)."""
    rng = np.random.default_rng(4)
    pool, page, kvh, d, c = 8, 4, 1, 8, 4
    kp = jnp.zeros((pool, page, kvh, d), jnp.float32)
    vp = jnp.zeros((pool, page, kvh, d), jnp.float32)
    # Row 0's window [its page, +1 junk] — the junk table entry is 0, which
    # is row 1's *real* page being written in the same call.
    rows = jnp.asarray([[5, 0], [0, 3]], jnp.int32)
    kn = jnp.asarray(rng.normal(size=(2, c, kvh, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(2, c, kvh, d)), jnp.float32)
    st = jnp.asarray([0, 0], jnp.int32)
    ct = jnp.asarray([2, 4], jnp.int32)
    k2, _ = ops.paged_kv_write_chunk(kp, vp, kn, vn, rows, st, ct,
                                     impl="pallas")
    np.testing.assert_allclose(np.asarray(k2[0, :4]), np.asarray(kn[1]))
    np.testing.assert_allclose(np.asarray(k2[5, :2]), np.asarray(kn[0, :2]))


# ---------------------------------------------------------------------------
# Length-adaptive kernel on ragged batches
# ---------------------------------------------------------------------------


def test_paged_decode_attention_length_adaptive_matches_ref():
    rng = np.random.default_rng(5)
    pool, page, kvh, d, b, npg, h = 16, 4, 2, 32, 4, 4, 8
    kp = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pool, page, kvh, d)), jnp.float32)
    table = jnp.asarray(rng.permutation(pool).reshape(b, npg), jnp.int32)
    # Fully empty, partial first page, exact page multiple, full table.
    lengths = jnp.asarray([0, 3, 8, 16], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    got = ops.paged_decode_attention(q, kp, vp, table, lengths)
    want = ops.paged_decode_attention(q, kp, vp, table, lengths, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(got[0]).max()) == 0.0  # empty sequence → zeros


# ---------------------------------------------------------------------------
# No recompilation across steps (jit compilation-cache counters)
# ---------------------------------------------------------------------------


def test_decode_fast_path_does_not_recompile_across_steps():
    model = PagedLM(CFG, jax.random.PRNGKey(1), impl="ref")
    rng = np.random.default_rng(6)
    prompts = _prompts(rng, (5, 9))

    def run():
        cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4)
        sched = Scheduler(model, cache, chunk=4)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new=10))
        return sched.run()

    first = run()
    fused = model._decode_many._cache_size()
    prefill = sum(f._cache_size() for f in model._prefill_cache.values())
    # Fused launches are pow2-bucketed: at most log2(page)+log2(max_new)+2
    # distinct scan lengths ever compile.
    assert fused <= 6
    second = run()
    assert second == first
    assert model._decode_many._cache_size() == fused  # zero new compiles
    assert sum(f._cache_size() for f in model._prefill_cache.values()) \
        == prefill


def test_scheduler_syncs_only_at_boundaries():
    """In steady-state decode the fused path must cover multiple model steps
    per scheduler iteration (i.e. per host sync)."""
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, (4,))
    cache = PagedKVCache.create(CFG, batch=1, max_len=64, page=8)
    sched = Scheduler(MODEL, cache, chunk=8)
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=16))
    sched.run()
    decode_records = [r for r in sched.stats.records if r.kind == "decode"]
    sched_iters = len({r.step for r in decode_records})
    assert len(decode_records) == 15          # max_new - 1 model steps
    assert sched_iters < len(decode_records)  # fused: fewer syncs than steps


def test_lookahead_pages_reclaimed_for_late_submission():
    """Lookahead prealloc maps pages for residents' whole remaining
    generations once the queue drains; a request submitted *after* that must
    still be admitted promptly — admission reclaims the unwritten lookahead
    pages instead of waiting for the holder to retire."""
    rng = np.random.default_rng(9)
    pa, pb, pc = _prompts(rng, (4, 4, 8))
    cache = PagedKVCache.create(CFG, batch=2, max_len=16, page=4,
                                pool_pages=6)
    sched = Scheduler(MODEL, cache, chunk=4)
    ra = Request(rid=0, prompt=pa, max_new=13)  # long-lived: peaks at 4 pages
    rb = Request(rid=1, prompt=pb, max_new=2)   # retires after one step
    sched.submit(ra)
    sched.submit(rb)
    sched.step()  # prefill both; lookahead maps A's remaining pages; B done
    assert rb.state is RequestState.FINISHED
    assert sched.cache._mapped(ra.slot) == 4  # A holds its full lookahead
    assert sched.cache.n_free == 2            # not enough for C (needs 3)
    rc = Request(rid=2, prompt=pc, max_new=2)
    sched.submit(rc)
    sched.step()
    assert rc.state is not RequestState.WAITING  # admitted via reclaim
    got = sched.run()
    # Every output still matches the static reference (row-wise model: C's
    # tokens are independent of its batch placement).
    want = static_batch_generate(
        MODEL, PagedKVCache.create(CFG, batch=2, max_len=32, page=4),
        [pa, pb], 13, chunk=4,
    )
    want_c = static_batch_generate(
        MODEL, PagedKVCache.create(CFG, batch=1, max_len=32, page=4),
        [pc], 2, chunk=4,
    )
    assert got[0] == want[0]
    assert got[1] == want[1][:2]
    assert got[2] == want_c[0]


# ---------------------------------------------------------------------------
# Fast path slots into the full scheduler (spot-check vs static batch)
# ---------------------------------------------------------------------------


def test_fused_scheduler_matches_static_batch_large_page():
    rng = np.random.default_rng(8)
    prompts = _prompts(rng, (11, 2, 7))
    max_new = 13

    cache_ref = PagedKVCache.create(CFG, batch=3, max_len=64, page=16)
    want = static_batch_generate(MODEL, cache_ref, prompts, max_new, chunk=8)

    cache = PagedKVCache.create(CFG, batch=3, max_len=64, page=16,
                                pool_pages=7)
    sched = Scheduler(MODEL, cache, chunk=8)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=max_new))
    got = sched.run()
    assert got == {i: want[i] for i in want}


# ---------------------------------------------------------------------------
# Pallas prefill kernel slotted into the engine (vs the einsum ref oracle)
# ---------------------------------------------------------------------------


def test_prefill_batch_pallas_kernel_matches_ref_engine():
    """The full engine prefill step (embed → chunk write → paged prefill
    attention → logits) with impl='pallas' stays allclose to the einsum ref
    path, including a padding row and a mid-page start."""
    model_p = PagedLM(CFG, jax.random.PRNGKey(0), impl="pallas")
    rng = np.random.default_rng(13)
    prompts = _prompts(rng, (6, 9))
    caches = {}
    logits = {}
    for impl, model in (("ref", MODEL), ("pallas", model_p)):
        cache = PagedKVCache.create(CFG, batch=3, max_len=32, page=4)
        for i, p in enumerate(prompts):
            cache = cache.allocate(i, cache.pages_for(len(p)))
        toks = np.zeros((3, 4), np.int32)
        toks[0] = prompts[0][:4]
        toks[1] = prompts[1][:4]
        lg, cache = model.prefill_batch(
            toks, np.asarray([4, 4, 0], np.int32),
            np.asarray([0, 1, 2], np.int32),
            np.asarray([0, 0, 0], np.int32), cache,
        )
        # Second chunk: ragged counts, rows at different positions.
        toks = np.zeros((3, 4), np.int32)
        toks[0, :2] = prompts[0][4:6]
        toks[1] = prompts[1][4:8]
        lg, cache = model.prefill_batch(
            toks, np.asarray([2, 4, 0], np.int32),
            np.asarray([0, 1, 2], np.int32),
            np.asarray([4, 4, 0], np.int32), cache,
        )
        caches[impl], logits[impl] = cache, np.asarray(lg)
    np.testing.assert_allclose(
        logits["pallas"][:2], logits["ref"][:2], rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(caches["pallas"].k_pages),
        np.asarray(caches["ref"].k_pages), rtol=1e-5, atol=1e-5,
    )


def test_scheduler_with_pallas_prefill_kernel_matches_static_batch():
    """End-to-end: continuous batching with the Pallas prefill kernel (and
    Pallas decode/append) reproduces the static reference token-for-token —
    greedy decode is bit-stable across the kernel/ref numerics here."""
    model_p = PagedLM(CFG, jax.random.PRNGKey(0), impl="pallas")
    rng = np.random.default_rng(14)
    prompts = _prompts(rng, (9, 4))
    max_new = 5
    want = static_batch_generate(
        MODEL, PagedKVCache.create(CFG, batch=2, max_len=32, page=4),
        prompts, max_new, chunk=4,
    )
    cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4)
    sched = Scheduler(model_p, cache, chunk=4)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=max_new))
    got = sched.run()
    assert got == {i: want[i] for i in want}
