"""Int8 KV page pools, end to end.

Quantize-on-write through both write ops (codes + per-(page-token, kv-head)
scales through the same indirect burst), both attention kernels reading the
quantized pool (in-VMEM dequant vs the shared ``dequantize_pages`` oracle
rule), the engine/scheduler serving mode (``kv_dtype='int8'``: donated
scale pools, eviction/replay rebuilding codes *and* scales bit-for-bit),
and the 8-bit packing factor in the PACK traffic accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import (
    elements_per_beat,
    packed_token_bytes,
    page_table_streams,
    paged_decode_traffic,
    paged_prefill_traffic,
    prefill_table_streams,
)
from repro.kernels import ops, ref
from repro.serve import (
    PagedKVCache,
    PagedLM,
    Request,
    Scheduler,
    static_batch_generate,
)

CFG = smoke_config("yi-6b")

# Quantization tolerance: int8 symmetric per-(token, kv-head) rounding on
# unit-normal KV rows; attention outputs are convex combinations of V rows,
# so the error stays at the per-element quant noise level.
QTOL = dict(rtol=0.0, atol=0.12)


def _int8_pool(pool, page, kvh, d):
    kp = jnp.zeros((pool, page, kvh, d), jnp.int8)
    vp = jnp.zeros((pool, page, kvh, d), jnp.int8)
    ks = jnp.ones((pool, page, kvh), jnp.float32)
    vs = jnp.ones((pool, page, kvh), jnp.float32)
    return kp, vp, ks, vs


def _models(kv_dtype=None):
    return (
        PagedLM(CFG, jax.random.PRNGKey(0), impl="ref", kv_dtype=kv_dtype),
        PagedLM(CFG, jax.random.PRNGKey(0), impl="pallas", kv_dtype=kv_dtype),
    )


def _prompts(rng, lens):
    return [rng.integers(0, CFG.vocab, n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Quantize-on-write round trips: write int8 → read both kernels → allclose
# to the fp32 oracle within quantization tolerance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_append_roundtrip_decode_matches_fp32_oracle(impl):
    rng = np.random.default_rng(0)
    pool, page, kvh, d, b, npg, h = 12, 4, 2, 32, 3, 3, 4
    kp8, vp8, ks, vs = _int8_pool(pool, page, kvh, d)
    kpf = jnp.zeros((pool, page, kvh, d), jnp.float32)
    vpf = jnp.zeros((pool, page, kvh, d), jnp.float32)
    table = jnp.asarray(rng.permutation(pool)[: b * npg].reshape(b, npg),
                        jnp.int32)
    lengths = jnp.asarray([0, 3, 7], jnp.int32)
    # Append a few tokens per sequence through the quantizing write.
    for _ in range(4):
        kn = jnp.asarray(rng.normal(size=(b, kvh, d)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(b, kvh, d)), jnp.float32)
        kp8, vp8, _, ks, vs = ops.paged_kv_append(
            kp8, vp8, kn, vn, table, lengths, k_scale=ks, v_scale=vs,
            impl=impl,
        )
        kpf, vpf, lengths = ops.paged_kv_append(
            kpf, vpf, kn, vn, table, lengths, impl="ref"
        )
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    got = ops.paged_decode_attention(
        q, kp8, vp8, table, lengths, k_scale=ks, v_scale=vs, impl=impl
    )
    want = ops.paged_decode_attention(q, kpf, vpf, table, lengths, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **QTOL)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_chunk_write_roundtrip_prefill_matches_fp32_oracle(impl):
    """Chunked writes straddling page boundaries, then chunk attention from
    the quantized pool, vs the full-precision write + oracle read."""
    rng = np.random.default_rng(1)
    pool, page, kvh, d, r, npg, c, h = 12, 4, 2, 32, 2, 3, 6, 4
    kp8, vp8, ks, vs = _int8_pool(pool, page, kvh, d)
    kpf = jnp.zeros((pool, page, kvh, d), jnp.float32)
    vpf = jnp.zeros((pool, page, kvh, d), jnp.float32)
    rows = jnp.asarray(rng.permutation(pool)[: r * npg].reshape(r, npg),
                       jnp.int32)
    kn = jnp.asarray(rng.normal(size=(r, c, kvh, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(r, c, kvh, d)), jnp.float32)
    st = jnp.asarray([2, 7], jnp.int32)          # both straddle a boundary
    ct = jnp.asarray([6, 5], jnp.int32)
    kp8, vp8, ks, vs = ops.paged_kv_write_chunk(
        kp8, vp8, kn, vn, rows, st, ct, k_scale=ks, v_scale=vs, impl=impl
    )
    kpf, vpf = ops.paged_kv_write_chunk(kpf, vpf, kn, vn, rows, st, ct,
                                        impl="ref")
    q = jnp.asarray(rng.normal(size=(r, c, h, d)), jnp.float32)
    got = ops.paged_prefill_attention(
        q, kp8, vp8, rows, st, ct, k_scale=ks, v_scale=vs, impl=impl
    )
    want = ops.paged_prefill_attention(q, kpf, vpf, rows, st, ct, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **QTOL)


def test_quantized_write_ops_pallas_bitwise_matches_ref():
    """The converter-kernel write path produces the identical int8 codes and
    scales as the oracle scatter — quantization happens once, before the
    stream, so the two paths can be compared bitwise."""
    rng = np.random.default_rng(2)
    pool, page, kvh, d, r, npg, c = 10, 4, 2, 16, 3, 2, 5
    kp8, vp8, ks, vs = _int8_pool(pool, page, kvh, d)
    rows = jnp.asarray(rng.permutation(pool)[: r * npg].reshape(r, npg),
                       jnp.int32)
    kn = jnp.asarray(rng.normal(size=(r, c, kvh, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(r, c, kvh, d)), jnp.float32)
    st = jnp.asarray([0, 3, 6], jnp.int32)
    ct = jnp.asarray([5, 0, 2], jnp.int32)       # incl. a padding row
    outs = [
        ops.paged_kv_write_chunk(kp8, vp8, kn, vn, rows, st, ct,
                                 k_scale=ks, v_scale=vs, impl=im)
        for im in ("ref", "pallas")
    ]
    for a, b in zip(*outs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("quantized", [False, True])
def test_append_past_table_row_drops_like_oracle(quantized):
    """A sequence whose length already fills its table row must append
    *nothing* under both implementations (the oracle's ``mode='drop'``) —
    regression for the converter path clamping the un-mapped slot gather
    onto physical page 0 and clobbering it."""
    rng = np.random.default_rng(8)
    pool, page, kvh, d = 6, 4, 1, 8
    kp = jnp.asarray(rng.integers(-5, 5, (pool, page, kvh, d)),
                     jnp.int8 if quantized else jnp.float32)
    vp = jnp.asarray(rng.integers(-5, 5, (pool, page, kvh, d)), kp.dtype)
    scales = (dict(k_scale=jnp.ones((pool, page, kvh), jnp.float32),
                   v_scale=jnp.ones((pool, page, kvh), jnp.float32))
              if quantized else {})
    kn = jnp.asarray(rng.normal(size=(1, kvh, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(1, kvh, d)), jnp.float32)
    table = jnp.asarray([[3, 5]], jnp.int32)
    full = jnp.asarray([8], jnp.int32)           # row capacity: 2 × 4
    for impl in ("ref", "pallas"):
        out = ops.paged_kv_append(kp, vp, kn, vn, table, full,
                                  impl=impl, **scales)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(kp))
        np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(vp))


def test_counts_zero_rows_exact_zero_under_int8():
    """Padding rows must output exact zeros from a quantized pool too — the
    mask logic is upstream of the dequant, under both implementations."""
    rng = np.random.default_rng(3)
    pool, page, kvh, d, r, npg, c, h = 8, 4, 2, 16, 3, 2, 4, 4
    kp8, vp8, ks, vs = _int8_pool(pool, page, kvh, d)
    # Fill the pool with junk codes/scales: a padding row must still be 0.
    kp8 = jnp.asarray(rng.integers(-127, 128, kp8.shape), jnp.int8)
    vp8 = jnp.asarray(rng.integers(-127, 128, vp8.shape), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 2.0, ks.shape), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 2.0, vs.shape), jnp.float32)
    rows = jnp.asarray(rng.permutation(pool)[: r * npg].reshape(r, npg),
                       jnp.int32)
    q = jnp.asarray(rng.normal(size=(r, c, h, d)), jnp.float32)
    st = jnp.asarray([0, 5, 0], jnp.int32)       # incl. degenerate start
    ct = jnp.asarray([4, 0, 0], jnp.int32)
    for impl in ("ref", "pallas"):
        out = np.asarray(ops.paged_prefill_attention(
            q, kp8, vp8, rows, st, ct, k_scale=ks, v_scale=vs, impl=impl
        ))
        assert np.isfinite(out).all()
        assert np.abs(out[1]).max() == 0.0
        assert np.abs(out[2]).max() == 0.0
    # Decode side: an empty sequence reads zero rows from the junk pool.
    lengths = jnp.asarray([0, 6, 8], jnp.int32)
    qd = jnp.asarray(rng.normal(size=(r, h, d)), jnp.float32)
    for impl in ("ref", "pallas"):
        out = np.asarray(ops.paged_decode_attention(
            qd, kp8, vp8, rows, lengths, k_scale=ks, v_scale=vs, impl=impl
        ))
        assert np.isfinite(out).all()
        assert np.abs(out[0]).max() == 0.0


# ---------------------------------------------------------------------------
# Engine / scheduler: kv_dtype='int8' end to end
# ---------------------------------------------------------------------------


def test_int8_pool_bytes_quartered_vs_fp32_halved_vs_bf16():
    kw = dict(batch=2, max_len=32, page=4)
    c32 = PagedKVCache.create(CFG, kv_dtype="fp32", **kw)
    c16 = PagedKVCache.create(CFG, kv_dtype="bf16", **kw)
    c8 = PagedKVCache.create(CFG, kv_dtype="int8", **kw)
    assert c8.k_pages.dtype == jnp.int8 and c8.quantized
    assert c8.k_pages.nbytes * 4 == c32.k_pages.nbytes
    assert c8.k_pages.nbytes * 2 == c16.k_pages.nbytes
    assert c8.k_scale.shape == c8.k_pages.shape[:-1]
    assert not c32.quantized and c32.k_scale is None


def test_int8_engine_pallas_matches_ref_within_quant_noise():
    """Full engine prefill + decode with impl='pallas' over int8 pools stays
    close to the impl='ref' int8 path (identical quantized writes, kernel
    vs oracle dequant read)."""
    rng = np.random.default_rng(4)
    prompts = _prompts(rng, (6, 9))
    logits, caches = {}, {}
    for model in _models("int8"):
        cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4,
                                    kv_dtype="int8")
        for i, p in enumerate(prompts):
            cache = cache.allocate(i, cache.pages_for(len(p) + 2))
        toks = np.zeros((2, 4), np.int32)
        toks[0] = prompts[0][:4]
        toks[1] = prompts[1][:4]
        lg, cache = model.prefill_batch(
            toks, np.asarray([4, 4], np.int32), np.asarray([0, 1], np.int32),
            np.asarray([0, 0], np.int32), cache,
        )
        lg, cache = model.decode_step(
            np.asarray([3, 5], np.int32), cache, np.asarray([True, True])
        )
        logits[model.impl], caches[model.impl] = np.asarray(lg), cache
    # Near-identical quantized pools on both paths: layer l>0 inputs differ
    # by the kernel-vs-oracle attention numerics of the layer below, so the
    # scales (and rarely a code, on a rounding knife-edge) can drift by
    # float-epsilon — but never by quantization-step amounts.
    np.testing.assert_allclose(
        np.asarray(caches["pallas"].k_pages, np.float32),
        np.asarray(caches["ref"].k_pages, np.float32), atol=1,
    )
    np.testing.assert_allclose(
        np.asarray(caches["pallas"].k_scale),
        np.asarray(caches["ref"].k_scale), rtol=1e-5,
    )
    np.testing.assert_allclose(logits["pallas"], logits["ref"],
                               rtol=1e-4, atol=1e-3)


def test_int8_scheduler_eviction_replay_rebuilds_scales_bit_for_bit():
    """Scale-pool donation + eviction/replay: a run that evicts and replays
    must produce the same tokens as the int8 static batch, and its final
    live pages/scales must match an eviction-free run — no stale scales
    survive a release/re-admission round trip."""
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, (8, 7))
    max_new = 8
    model, _ = _models("int8")

    want = static_batch_generate(
        model, PagedKVCache.create(CFG, batch=2, max_len=16, page=4,
                                   kv_dtype="int8"),
        prompts, max_new, chunk=4,
    )
    # 6-page pool: both requests peak at 4 pages → mid-decode eviction.
    cache = PagedKVCache.create(CFG, batch=2, max_len=16, page=4,
                                pool_pages=6, kv_dtype="int8")
    sched = Scheduler(model, cache, chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    got = sched.run()
    assert sched.stats.n_evictions >= 1
    assert got == {i: want[i] for i in want}

    # Ample-pool run: no evictions; compare each request's live pages+scales.
    cache2 = PagedKVCache.create(CFG, batch=2, max_len=16, page=4,
                                 kv_dtype="int8")
    sched2 = Scheduler(model, cache2, chunk=4)
    for i, p in enumerate(prompts):
        sched2.submit(Request(rid=i, prompt=p, max_new=max_new))
    got2 = sched2.run()
    assert got2 == got
    # Both runs retired everything; every page went back to the free pool
    # and the *content* of the pools for each sequence was identical while
    # live (asserted transitively through the bit-equal token streams above
    # — tokens depend on codes AND scales, so a stale scale would diverge).
    assert sched.cache.n_free == 6


def test_int8_scheduler_matches_pallas_kernels_end_to_end():
    """Continuous batching with impl='pallas' int8 (quantized chunk writes,
    both quantized kernels) reproduces the impl='ref' int8 token stream —
    greedy decode is bit-stable across the kernel/oracle dequant numerics
    on this workload."""
    ref_m, pal_m = _models("int8")
    rng = np.random.default_rng(6)
    prompts = _prompts(rng, (9, 4))
    outs = {}
    for model in (ref_m, pal_m):
        cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4,
                                    kv_dtype="int8")
        sched = Scheduler(model, cache, chunk=4)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new=5))
        outs[model.impl] = sched.run()
    assert outs["pallas"] == outs["ref"]


def test_scheduler_rejects_mismatched_kv_dtype():
    model, _ = _models("int8")
    cache = PagedKVCache.create(CFG, batch=1, max_len=8, page=4)  # fp32
    with pytest.raises(ValueError):
        Scheduler(model, cache, chunk=4)
    # Width mismatches among float pools are rejected too — a bf16 model
    # over fp32 pools would silently halve every PACK byte count.
    model16 = PagedLM(CFG, jax.random.PRNGKey(0), impl="ref",
                      kv_dtype="bf16")
    with pytest.raises(ValueError):
        Scheduler(model16, cache, chunk=4)
    # And create() accepts the model's dtype object directly (the benchmark
    # path), guaranteeing agreement.
    cache16 = PagedKVCache.create(CFG, batch=1, max_len=8, page=4,
                                  kv_dtype=model16.kv_dtype)
    Scheduler(model16, cache16, chunk=4)


# ---------------------------------------------------------------------------
# 8-bit PACK traffic accounting
# ---------------------------------------------------------------------------


def test_packed_token_bytes_packing_factor():
    # 8-bit elements quadruple the FP32 packing factor (bus/elem, §II-C)...
    assert elements_per_beat(256, 8) == 4 * elements_per_beat(256, 32)
    # ...which is exactly the byte scaling packed_token_bytes applies.
    assert packed_token_bytes(256, elem_bits=8) * 4 == packed_token_bytes(256)
    assert packed_token_bytes(256, elem_bits=16) * 2 == packed_token_bytes(256)
    assert packed_token_bytes(256, elem_bits=8, scale_bytes_per_token=16) \
        == 256 // 4 + 16


def test_paged_decode_traffic_elem8_vs_elem32():
    kw = dict(lengths=[5, 12], page_size=4, pages_per_seq=4, token_bytes=256)
    t32 = paged_decode_traffic(**kw)
    t8 = paged_decode_traffic(elem_bits=8, **kw)
    # BASE is the packing-oblivious full-width stream: unchanged.
    assert t8.base_bytes == t32.base_bytes == 2 * 4 * 4 * 256
    # PACK packs the narrow elements densely: exactly a quarter.
    assert t8.pack_bytes * 4 == t32.pack_bytes == 5 * 4 * 256
    assert t8.useful_bytes * 4 == t32.useful_bytes
    # Index fetch is element-width independent.
    assert t8.index_bus_bytes_pack == t32.index_bus_bytes_pack
    # Efficiencies: PACK stays high; BASE quarters (narrow-beat penalty).
    assert t8.pack_efficiency == pytest.approx(t32.pack_efficiency, rel=0.05)
    assert t8.base_efficiency == pytest.approx(t32.base_efficiency / 4)


def test_paged_decode_traffic_elem8_page_boundary():
    """Length exactly on a page multiple: the 8-bit path must touch the same
    page count as the 32-bit path (page math is width-independent)."""
    for length in (4, 8, 16):  # page_size=4 → exact page multiples
        t32 = paged_decode_traffic([length], 4, 4, 256)
        t8 = paged_decode_traffic([length], 4, 4, 256, elem_bits=8)
        pages = length // 4
        assert t32.pack_bytes == pages * 4 * 256
        assert t8.pack_bytes == pages * 4 * 64
        assert t8.index_bus_bytes_pack == t32.index_bus_bytes_pack


def test_paged_prefill_traffic_elem8_vs_elem32_with_boundary():
    # Row 0 ends exactly on a page boundary (start+count = 8 = 2 pages);
    # row 1 straddles; page math identical across widths.
    kw = dict(starts=[4, 5], counts=[4, 6], page_size=4, pages_per_seq=4,
              token_bytes=256)
    t32 = paged_prefill_traffic(**kw)
    t8 = paged_prefill_traffic(elem_bits=8, **kw)
    ctx_pages = 2 + 3     # ceil(8/4), ceil(11/4)
    chunk_pages = 1 + 2   # pages covering [4,8), [5,11)
    assert t32.pack_bytes == (ctx_pages + chunk_pages) * 4 * 256
    assert t8.pack_bytes * 4 == t32.pack_bytes
    assert t8.base_bytes == t32.base_bytes       # full-width BASE + granules
    assert t8.index_bus_bytes_pack == t32.index_bus_bytes_pack


def test_int8_scale_sideband_charged_to_pack():
    t = paged_decode_traffic([8], 4, 4, token_bytes=256, elem_bits=8,
                             scale_bytes_per_token=16)
    # 2 pages × 4 tokens × (64 narrow + 16 scale) bytes.
    assert t.pack_bytes == 2 * 4 * (64 + 16)
    assert t.useful_bytes == 8 * (64 + 16)


def test_stream_descriptors_carry_packed_element_width():
    table = np.array([[3, 1, 0, 0]])
    streams32 = page_table_streams(table, np.array([5]), page_size=4,
                                   token_bytes=256)
    streams8 = page_table_streams(table, np.array([5]), page_size=4,
                                  token_bytes=256, kv_elem_bits=8,
                                  scale_bytes_per_token=16)
    assert streams32[0].elem_bits == 4 * 256 * 8
    assert streams8[0].elem_bits == 4 * (64 + 16) * 8
    np.testing.assert_array_equal(streams8[0].indices, streams32[0].indices)
    p8 = prefill_table_streams(table, np.array([0]), np.array([4]),
                               page_size=4, token_bytes=256, kv_elem_bits=8)
    assert all(s.elem_bits == 4 * 64 * 8 for s in p8)


def test_int8_scheduler_stats_reflect_packing_factor():
    """Same workload under fp32 and int8 pools: BASE bytes identical (the
    packing-oblivious stream), PACK bytes ~quartered (up to the scale
    sideband and granule rounding), so the PACK-vs-BASE win quadruples."""
    rng = np.random.default_rng(7)
    prompt_sets = [_prompts(rng, (6, 9))]
    stats = {}
    for kv_dtype in (None, "int8"):
        model = PagedLM(CFG, jax.random.PRNGKey(0), impl="ref",
                        kv_dtype=kv_dtype)
        cache = PagedKVCache.create(CFG, batch=2, max_len=32, page=4,
                                    kv_dtype=kv_dtype)
        sched = Scheduler(model, cache, chunk=4)
        for i, p in enumerate(prompt_sets[0]):
            sched.submit(Request(rid=i, prompt=p, max_new=6))
        sched.run()
        stats[kv_dtype or "fp32"] = sched.stats
    fp, i8 = stats["fp32"], stats["int8"]
    assert i8.base_bytes == fp.base_bytes
    assert i8.prefill_base_bytes == fp.prefill_base_bytes
    # Scale sideband = 1/hd of the narrow payload here (hd=32): pack bytes
    # land between a clean 1/4 and 1/4 · (1 + 4/hd) of the fp32 bytes.
    assert fp.pack_bytes / 4 <= i8.pack_bytes < fp.pack_bytes / 3
    assert i8.base_efficiency < fp.base_efficiency / 3
    assert i8.pack_efficiency > 0.8 * fp.pack_efficiency
