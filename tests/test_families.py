"""Family-agnostic serving: recurrent (RWKV6/Mamba) models through the same
scheduler as the paged transformer, bit-for-bit against direct forwards.

What this file locks down (ISSUE 8 acceptance criteria):

* scheduler-served RWKV6 and Mamba outputs are **bit-for-bit equal** to a
  direct sequential forward (`recurrent_reference_generate`) at the same
  batch shape, across prefill chunkings and fused-decode interleavings;
* eviction → replay round-trips reproduce the fault-free tokens exactly
  (replay-by-re-prefill from a zeroed state row);
* mixed transformer + recurrent workloads run step-interleaved with the
  family-generic invariant oracle asserted after every step;
* the strided state read/write ops match their ref oracles bitwise and
  never disturb non-target rows;
* the strided-burst accounting dialect (`recurrent_state_streams`,
  `recurrent_decode_traffic`/`recurrent_prefill_traffic`) is internally
  consistent: PACK efficiency ≈ 1, BASE efficiency = occupancy, no index
  bus term;
* the scheduler module itself never references the paged implementation —
  it speaks only the `ServableFamily` protocol.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.packing import (
    recurrent_decode_traffic,
    recurrent_prefill_traffic,
)
from repro.core.streams import (
    BurstKind,
    StridedStream,
    recurrent_state_streams,
)
from repro.kernels import ops
from repro.serve import (
    OutOfPages,
    PagedKVCache,
    PagedLM,
    RecurrentFamily,
    RecurrentLM,
    RecurrentStatePool,
    Request,
    RequestState,
    Scheduler,
    check_scheduler_invariants,
    recurrent_reference_generate,
    static_batch_generate,
)

RWKV_CFG = smoke_config("rwkv6-3b")
DENSE_CFG = smoke_config("yi-6b")


def _prompts(rng, vocab, lens):
    return [np.asarray(rng.integers(0, vocab, n), np.int32) for n in lens]


def _drive(sched, requests, max_steps=500):
    for r in requests:
        sched.submit(r)
    check_scheduler_invariants(sched, requests)
    steps = 0
    while sched.queue or sched.resident:
        sched.step()
        check_scheduler_invariants(sched, requests)
        steps += 1
        assert steps < max_steps, "run failed to drain"
    return {rid: r.generated for rid, r in sorted(sched.finished.items())}


# ---------------------------------------------------------------------------
# Scheduler-served output == direct sequential forward, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,chunk", [("rwkv6", 4), ("rwkv6", 8),
                                        ("mamba", 4), ("mamba", 8)])
def test_scheduled_matches_direct_forward(arch, chunk):
    cfg = RWKV_CFG if arch == "rwkv6" else DENSE_CFG
    rng = np.random.default_rng(chunk + (0 if arch == "rwkv6" else 100))
    model = RecurrentLM(cfg, jax.random.PRNGKey(0), arch=arch, impl="ref")
    prompts = _prompts(rng, cfg.vocab, (8, 7, 12))
    max_new = 8
    want = recurrent_reference_generate(model, model.init_pool(3), prompts,
                                        max_new)
    sched = Scheduler(model, model.init_pool(3), chunk=chunk)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    out = _drive(sched, reqs)
    assert out == {i: want[i] for i in range(3)}
    assert sched.family.name == arch


def test_scheduled_matches_direct_forward_ragged_arrivals():
    """Late submissions change interleaving, never tokens: row masking keeps
    inactive slots bit-exact while other rows prefill/decode."""
    cfg = RWKV_CFG
    rng = np.random.default_rng(7)
    model = RecurrentLM(cfg, jax.random.PRNGKey(0), impl="ref")
    prompts = _prompts(rng, cfg.vocab, (10, 3, 6))
    max_new = 6
    want = recurrent_reference_generate(model, model.init_pool(3), prompts,
                                        max_new)
    sched = Scheduler(model, model.init_pool(3), chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    sched.submit(reqs[0])
    sched.step()  # rid 0 alone in flight
    sched.submit(reqs[1])
    sched.step()
    sched.submit(reqs[2])
    while sched.queue or sched.resident:
        sched.step()
        check_scheduler_invariants(sched, reqs)
    out = {rid: r.generated for rid, r in sorted(sched.finished.items())}
    assert out == {i: want[i] for i in range(3)}


# ---------------------------------------------------------------------------
# Eviction → replay round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["rwkv6", "mamba"])
def test_eviction_replay_round_trip(arch):
    """Force-evict a mid-decode resident: replay re-prefills from a zeroed
    state row and reproduces the fault-free tokens exactly."""
    cfg = RWKV_CFG if arch == "rwkv6" else DENSE_CFG
    rng = np.random.default_rng(11)
    model = RecurrentLM(cfg, jax.random.PRNGKey(0), arch=arch, impl="ref")
    prompts = _prompts(rng, cfg.vocab, (9, 6))
    max_new = 8
    want = recurrent_reference_generate(model, model.init_pool(2), prompts,
                                        max_new)
    sched = Scheduler(model, model.init_pool(2), chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sched.submit(r)
    # Step until rid 1 is decoding with partial output, then evict it.
    for _ in range(50):
        sched.step()
        check_scheduler_invariants(sched, reqs)
        victim = next((r for r in sched.resident
                       if r.rid == 1 and r.state is RequestState.RUNNING
                       and r.generated and not r.done), None)
        if victim is not None:
            break
    assert victim is not None, "rid 1 never reached mid-decode"
    partial = list(victim.generated)
    sched._evict(victim)
    check_scheduler_invariants(sched, reqs)
    out = _drive(sched, [])
    assert sched.stats.n_evictions >= 1
    assert out[1][:len(partial)] == partial  # replay re-derived the prefix
    assert out == {i: want[i] for i in range(2)}


def test_out_of_slots_staggers_admission():
    """More requests than state slots: admission staggers, everyone drains."""
    cfg = RWKV_CFG
    rng = np.random.default_rng(13)
    model = RecurrentLM(cfg, jax.random.PRNGKey(0), impl="ref")
    prompts = _prompts(rng, cfg.vocab, (8, 7, 12, 5))
    sched = Scheduler(model, model.init_pool(2), chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    out = _drive(sched, reqs)
    assert sorted(out) == [0, 1, 2, 3]
    assert all(len(v) == 5 for v in out.values())
    assert sched.family.free_units == 2  # all slots returned


def test_prefix_sharing_rejected_for_recurrent():
    model = RecurrentLM(RWKV_CFG, jax.random.PRNGKey(0), impl="ref")
    with pytest.raises(ValueError, match="refcounted"):
        Scheduler(model, model.init_pool(2), prefix_sharing=True)


def test_state_pool_exhaustion_raises_typed():
    model = RecurrentLM(RWKV_CFG, jax.random.PRNGKey(0), impl="ref")
    fam = model.bind(model.init_pool(2))
    fam.alloc_state(0, 1)
    fam.alloc_state(1, 1)
    with pytest.raises(OutOfPages):
        fam.alloc_state(0, 1)  # double-alloc of an owned slot
    fam.release(0)
    fam.alloc_state(0, 1)  # released slot is reusable
    assert fam.free_units == 0


# ---------------------------------------------------------------------------
# Mixed transformer + recurrent workload, step-interleaved
# ---------------------------------------------------------------------------


def test_mixed_families_interleaved():
    """A paged transformer and a recurrent model serve side by side, the
    family-generic invariant oracle asserted on both after every step, and
    both match their family's reference generation bit-for-bit."""
    rng = np.random.default_rng(17)
    pm = PagedLM(DENSE_CFG, jax.random.PRNGKey(0), impl="ref")
    rm = RecurrentLM(RWKV_CFG, jax.random.PRNGKey(0), impl="ref")
    p_prompts = _prompts(rng, DENSE_CFG.vocab, (8, 7, 12))
    r_prompts = _prompts(rng, RWKV_CFG.vocab, (6, 11, 4))
    max_new = 6

    p_want = static_batch_generate(
        pm, PagedKVCache.create(DENSE_CFG, batch=3, max_len=32, page=4),
        p_prompts, max_new, chunk=4,
    )
    r_want = recurrent_reference_generate(rm, rm.init_pool(3), r_prompts,
                                          max_new)

    ps = Scheduler(pm, PagedKVCache.create(DENSE_CFG, batch=3, max_len=32,
                                           page=4), chunk=4)
    rs = Scheduler(rm, rm.init_pool(3), chunk=4)
    p_reqs = [Request(rid=i, prompt=p, max_new=max_new)
              for i, p in enumerate(p_prompts)]
    r_reqs = [Request(rid=i, prompt=p, max_new=max_new)
              for i, p in enumerate(r_prompts)]
    for r in p_reqs:
        ps.submit(r)
    for r in r_reqs:
        rs.submit(r)
    for _ in range(200):
        if not (ps.queue or ps.resident or rs.queue or rs.resident):
            break
        if ps.queue or ps.resident:
            ps.step()
            check_scheduler_invariants(ps, p_reqs)
        if rs.queue or rs.resident:
            rs.step()
            check_scheduler_invariants(rs, r_reqs)
    assert not (ps.queue or ps.resident or rs.queue or rs.resident)
    p_out = {rid: r.generated for rid, r in ps.finished.items()}
    r_out = {rid: r.generated for rid, r in rs.finished.items()}
    assert p_out == {i: p_want[i] for i in range(3)}
    assert r_out == {i: r_want[i] for i in range(3)}
    # The two families report disjoint accounting dialects.
    assert any(s.kind is BurstKind.INDIRECT
               for rec in ps.stats.records for s in rec.streams)
    assert all(s.kind is not BurstKind.INDIRECT
               for rec in rs.stats.records for s in rec.streams)
    assert any(s.kind is BurstKind.STRIDED
               for rec in rs.stats.records for s in rec.streams)


# ---------------------------------------------------------------------------
# Strided state read/write ops vs ref oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 4, 2, 64, 64), (3, 4, 128), (2, 3, 3, 256)])
def test_recurrent_state_ops_match_ref(shape):
    rng = np.random.default_rng(23)
    pool = jnp.asarray(rng.normal(size=shape), jnp.float32)
    l, b = shape[:2]
    for slot in range(b):
        got = ops.recurrent_state_read(pool, slot)
        want = ops.recurrent_state_read(pool, slot, impl="ref")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert got.shape == (l,) + shape[2:]
    value = jnp.asarray(rng.normal(size=(l,) + shape[2:]), jnp.float32)
    for slot in range(b):
        got = ops.recurrent_state_write(pool, slot, value)
        want = ops.recurrent_state_write(pool, slot, value, impl="ref")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # Non-target rows are untouched bitwise.
        mask = np.ones(b, bool)
        mask[slot] = False
        np.testing.assert_array_equal(
            np.asarray(got)[:, mask], np.asarray(pool)[:, mask]
        )
        # Target rows hold the new value.
        np.testing.assert_array_equal(np.asarray(got)[:, slot],
                                      np.asarray(value))


def test_replay_zeroes_only_target_slot():
    model = RecurrentLM(RWKV_CFG, jax.random.PRNGKey(0), impl="ref")
    fam = model.bind(model.init_pool(3))
    # Dirty all state rows, then replay slot 1.
    fam.pool.tensors = {
        k: t + jnp.asarray(1.0, t.dtype) for k, t in fam.pool.tensors.items()
    }
    before = {k: np.asarray(t) for k, t in fam.pool.tensors.items()}
    fam.replay(1)
    for k, t in fam.pool.tensors.items():
        a = np.asarray(t)
        assert (a[:, 1] == 0).all(), f"{k}: slot 1 not zeroed"
        mask = np.ones(a.shape[1], bool)
        mask[1] = False
        np.testing.assert_array_equal(a[:, mask], before[k][:, mask])


# ---------------------------------------------------------------------------
# Strided-burst accounting dialect
# ---------------------------------------------------------------------------


def test_recurrent_state_streams_descriptors():
    streams = recurrent_state_streams([1, 3], batch=4, n_layers=2,
                                      row_bytes=(4096, 512))
    # 2 slots × 2 tensors × (read + write) = 8 descriptors.
    assert len(streams) == 8
    assert all(isinstance(s, StridedStream) for s in streams)
    assert {s.base for s in streams} == {1, 3}
    assert all(s.stride == 4 and s.count == 2 for s in streams)
    assert {s.elem_bits for s in streams} == {4096 * 8, 512 * 8}
    # batch == 1 degenerates to the contiguous BASE converter (stride 1).
    assert all(s.stride == 1 for s in
               recurrent_state_streams([0], 1, 2, (64,)))


def test_recurrent_traffic_accounting():
    sb = 1000
    t = recurrent_decode_traffic(n_active=3, batch=8, state_bytes=sb)
    assert t.useful_bytes == 2 * 3 * sb
    assert t.base_bytes == 2 * 8 * sb
    assert t.index_bus_bytes_pack == 0  # the stride IS the descriptor
    assert t.useful_bytes <= t.pack_bytes < t.useful_bytes + 32
    # Idle step moves nothing under PACK.
    assert recurrent_decode_traffic(0, 8, sb).pack_bytes == 0
    p = recurrent_prefill_traffic([4, 0, 2], batch=8, state_bytes=sb)
    assert p.useful_bytes == 2 * 2 * sb  # two active rows, chunk-amortized
    assert p.base_bytes == 2 * 8 * 4 * sb  # padded pool per chunk position


def test_scheduler_records_strided_pack_efficiency():
    rng = np.random.default_rng(29)
    model = RecurrentLM(RWKV_CFG, jax.random.PRNGKey(0), impl="ref")
    # 4 slots, 3 requests: occupancy < 1, so BASE pays for the idle row.
    sched = Scheduler(model, model.init_pool(4), chunk=4)
    reqs = [Request(rid=i, prompt=p, max_new=5)
            for i, p in enumerate(_prompts(rng, RWKV_CFG.vocab, (8, 5, 7)))]
    _drive(sched, reqs)
    st = sched.stats
    assert st.pack_bytes > 0 and st.base_bytes > 0
    assert 0.9 <= st.pack_efficiency <= 1.0  # dense strided bursts
    assert st.base_efficiency <= 0.75  # at most 3 of 4 rows ever live
    assert st.pack_efficiency > st.base_efficiency


# ---------------------------------------------------------------------------
# Protocol purity: the scheduler speaks only ServableFamily
# ---------------------------------------------------------------------------


def test_scheduler_module_is_family_agnostic():
    import repro.serve.scheduler as sched_mod

    src = inspect.getsource(sched_mod)
    assert "PagedLM" not in src
    assert "PagedKVCache" not in src
    assert "kv_pages" not in src and "page_table" not in src
    assert "ServableFamily" in src


def test_scheduler_rejects_non_family():
    with pytest.raises(TypeError):
        Scheduler(object())
