"""Substrate tests: optimizer, data pipeline, checkpointing, fault tolerance."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import smoke_config
from repro.data import TokenDataset, synthetic_corpus
from repro.models import lm
from repro.optim import OptimizerConfig, make_optimizer
from repro.parallel.sharding import make_rules
from repro.runtime import FaultToleranceConfig, StragglerWatchdog, TrainController
from repro.train import make_train_step

RULES = make_rules(with_pod=False)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    opt = make_optimizer(OptimizerConfig(name=name, lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0))
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 256)), jnp.float32)
    params = {"w": jnp.zeros((8, 256))}
    state = opt.init(params)
    for step in range(100):
        grads = {"w": params["w"] - target}
        params, state, _ = opt.update(grads, state, params, step)
    err = float(jnp.abs(params["w"] - target).mean())
    assert err < 0.3, err


def test_adafactor_state_is_factored():
    opt = make_optimizer(OptimizerConfig(name="adafactor"))
    params = {"big": jnp.zeros((512, 512)), "small": jnp.zeros((16,))}
    st = opt.init(params)
    assert "vr" in st["big"] and st["big"]["vr"].shape == (512,)
    assert "v" in st["small"]
    # factored state is ~2/N of the dense second moment
    dense = 512 * 512
    fact = 512 + 512
    assert fact < dense // 100


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_dataset_deterministic_and_host_disjoint(tmp_path):
    path = str(tmp_path / "corpus")
    synthetic_corpus(path, n_tokens=20000, vocab=64, seed=0)
    ds0 = TokenDataset(path, seq_len=32, global_batch=8, n_hosts=2, host_id=0)
    ds1 = TokenDataset(path, seq_len=32, global_batch=8, n_hosts=2, host_id=1)
    b0a, b0b = ds0.batch(3), ds0.batch(3)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # deterministic
    b1 = ds1.batch(3)
    assert not np.array_equal(b0a["tokens"], b1["tokens"])       # disjoint hosts
    # targets are tokens shifted by one
    np.testing.assert_array_equal(b0a["tokens"][:, 1:], b0a["targets"][:, :-1])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    for s in [10, 20, 30]:
        mgr.save(s, tree)
    assert mgr.all_steps() == [20, 30]  # retention
    restored = mgr.restore(30, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10.0))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.ones((256, 256))}
    mgr.save_async(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1
    # a leftover tmp dir must not be visible as a checkpoint
    os.makedirs(str(tmp_path / "step_00000099.tmp_"), exist_ok=True)
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# Fault tolerance: preemption + resume = uninterrupted run
# ---------------------------------------------------------------------------


def _tiny_setup(tmp_path, ckpt_every=5):
    cfg = smoke_config("yi-6b")
    opt = make_optimizer(OptimizerConfig(lr=1e-2, warmup_steps=2, total_steps=100))
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step_fn_raw = make_train_step(cfg, opt, RULES)
    jitted = jax.jit(step_fn_raw)

    def step_fn(state, batch, step):
        params, opt_state, metrics = jitted(state["params"], state["opt"], batch, step)
        return {"params": params, "opt": opt_state}, metrics

    rng = np.random.default_rng(42)
    data = rng.integers(0, cfg.vocab, (64, 33))

    def make_batch(step):
        rows = data[(step * 4 + np.arange(4)) % 64]
        return {
            "tokens": jnp.asarray(rows[:, :-1]),
            "targets": jnp.asarray(rows[:, 1:]),
            "mask": jnp.ones((4, 32)),
        }

    ft = FaultToleranceConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=ckpt_every)
    state0 = {"params": params, "opt": opt_state}
    return step_fn, make_batch, ft, state0


def test_preempt_resume_bitwise_equals_straight_run(tmp_path):
    step_fn, make_batch, ft, state0 = _tiny_setup(tmp_path)

    # Straight run to 12 steps.
    c1 = TrainController(step_fn, make_batch, dataclasses.replace(
        ft, ckpt_dir=str(tmp_path / "a")))
    final_a = c1.run(state0, 12, log_every=100)

    # Preempted at step 8 (after ckpt at 5), then resumed.
    c2 = TrainController(step_fn, make_batch, dataclasses.replace(
        ft, ckpt_dir=str(tmp_path / "b")))
    with pytest.raises(KeyboardInterrupt):
        c2.run(state0, 12, preempt_at=8, log_every=100)
    c3 = TrainController(step_fn, make_batch, dataclasses.replace(
        ft, ckpt_dir=str(tmp_path / "b")))
    final_b = c3.run(state0, 12, log_every=100)

    for a, b in zip(
        jax.tree_util.tree_leaves(final_a["params"]),
        jax.tree_util.tree_leaves(final_b["params"]),
    ):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_loss_decreases_on_learnable_data(tmp_path):
    step_fn, make_batch, ft, state0 = _tiny_setup(tmp_path, ckpt_every=50)
    c = TrainController(step_fn, make_batch, ft)
    c.run(state0, 30, log_every=1000)
    first = np.mean([h["loss"] for h in c.history[:5]])
    last = np.mean([h["loss"] for h in c.history[-5:]])
    assert last < first - 0.2, (first, last)


def test_straggler_watchdog():
    wd = StragglerWatchdog(FaultToleranceConfig(straggler_factor=2.0))
    for _ in range(10):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)        # straggler detected
    assert wd.stragglers == 1
    assert abs(wd.ema - 1.0) < 1e-6  # baseline not poisoned
    with pytest.raises(TimeoutError):
        StragglerWatchdog(
            FaultToleranceConfig(step_timeout_s=0.5)
        ).observe(1.0)
