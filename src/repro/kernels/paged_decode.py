"""Paged-KV decode attention kernel — the flagship indirect-stream application.

A paged KV cache stores sequences as scattered fixed-size physical pages; the
page table is exactly an AXI-Pack *indirect stream descriptor*: a memory-
resident index array resolved near memory.  Here the page table rides the
scalar-prefetch channel and the BlockSpec ``index_map`` turns each entry into
a direct HBM→VMEM page DMA — K/V pages are packed densely into VMEM and the
core never touches an address computation (the paper's element request
generator, verbatim in Pallas).

Supports an int8-quantized KV pool (per-(page-token, kv-head) scales): the
TPU analogue of packing *narrower elements* onto the bus — a quarter of the
fp32 HBM traffic (half of bf16) for the bandwidth-bound decode step,
exactly the paper's element-size argument in §III-E.  The scale pages ride
the same clamped index map as their K/V pages; the write side
(``ops.paged_kv_append`` / ``ops.paged_kv_write_chunk`` with scale pools)
quantizes on write through the same indices.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_body(
    # scalar prefetch
    page_table_ref,   # (B * n_pages,) physical page ids
    lengths_ref,      # (B,) current KV length per sequence
    used_ref,         # (B,) mapped-page count per sequence (ceil(len/page))
    # inputs
    q_ref,            # (1, H, D)
    k_ref,            # (1, page, KVH, D)
    v_ref,
    k_scale_ref,      # (1, page, KVH) or None
    v_scale_ref,
    # output
    o_ref,            # (1, H, D)
    # scratch
    m_ref,
    l_ref,
    acc_ref,
    *,
    page: int,
    n_pages: int,
    kvh: int,
    rep: int,
    d: int,
    scale: float,
    quantized: bool,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lengths_ref[b]

    @pl.when(j * page < seq_len)
    def _update():
        k = k_ref[0].astype(jnp.float32)                  # (page, KVH, D)
        v = v_ref[0].astype(jnp.float32)
        if quantized:
            k = k * k_scale_ref[0].astype(jnp.float32)[..., None]
            v = v * v_scale_ref[0].astype(jnp.float32)[..., None]
        q = q_ref[0].astype(jnp.float32)                  # (H, D)
        qg = q.reshape(kvh, rep, d)
        # scores: (KVH, rep, page)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
        ) * scale
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (kvh, rep, page), 2)
        mask = pos < seq_len
        s = jnp.where(mask, s, NEG_INF)

        h = kvh * rep
        s_h = s.reshape(h, page)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s_h, axis=1, keepdims=True))
        p = jnp.where(mask.reshape(h, page), jnp.exp(s_h - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True), l_ref.shape
        )
        # acc update: p (KVH, rep, page) × v (page, KVH, D) → (KVH, rep, D)
        pv = jax.lax.dot_general(
            p.reshape(kvh, rep, page),
            v,
            (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(h, d)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(j == n_pages - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention_kernel(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode one token per sequence against a paged KV pool.

    q:          (B, H, D)
    k/v_pages:  (P, page, KVH, D) — int8 when ``k_scale``/``v_scale`` given
    page_table: (B, n_pages) int32 physical page ids (pad with 0)
    lengths:    (B,) int32 valid KV length per sequence

    The page walk is *length-adaptive*: per-sequence mapped-page counts ride
    the scalar-prefetch channel alongside the table, and the BlockSpec index
    map clamps every grid step past a sequence's last mapped page to that
    last page.  Revisited blocks are not re-fetched, so fully-unmapped tail
    pages issue no HBM→VMEM DMAs (their compute is already skipped by the
    ``j * page < len`` predicate) — short sequences in a long-table batch
    stream only what they actually own.  The batch grid dimension is
    declared ``parallel`` (sequences are independent); only the page walk is
    ``arbitrary`` (it carries the running softmax state).
    """
    b, h, d = q.shape
    p_tot, page, kvh, _ = k_pages.shape
    n_pages = page_table.shape[1]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    quantized = k_scale is not None

    flat_table = page_table.reshape(-1).astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    used = jnp.maximum(-(-lengths // page), 1).astype(jnp.int32)

    def table_idx(b_, j, pt_ref, len_ref, used_ref):
        jj = jnp.minimum(j, used_ref[b_] - 1)
        return (pt_ref[b_ * n_pages + jj], 0, 0, 0)

    def scale_idx(b_, j, pt_ref, len_ref, used_ref):
        jj = jnp.minimum(j, used_ref[b_] - 1)
        return (pt_ref[b_ * n_pages + jj], 0, 0)

    in_specs = [
        pl.BlockSpec((1, h, d), lambda b_, j, pt, ln, us: (b_, 0, 0)),
        pl.BlockSpec((1, page, kvh, d), table_idx),
        pl.BlockSpec((1, page, kvh, d), table_idx),
    ]
    args = [q, k_pages, v_pages]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, page, kvh), scale_idx),
            pl.BlockSpec((1, page, kvh), scale_idx),
        ]
        args += [k_scale, v_scale]

    body = functools.partial(
        _paged_body,
        page=page,
        n_pages=n_pages,
        kvh=kvh,
        rep=rep,
        d=d,
        scale=scale,
        quantized=quantized,
    )
    if not quantized:
        body = functools.partial(_drop_scale_refs, body)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, d), lambda b_, j, pt, ln, us: (b_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(flat_table, lengths, used, *args)


def _drop_scale_refs(body, pt, ln, us, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                     acc_ref):
    return body(pt, ln, us, q_ref, k_ref, v_ref, None, None, o_ref, m_ref,
                l_ref, acc_ref)
