"""Packed ELL SpMV kernel (the spmv / prank / sssp indirect benchmarks).

CSR on a vector machine iterates rows and gathers ``x[cols]`` — the paper's
flagship indirect stream.  The TPU-native layout is padded ELL: ``vals`` and
``cols`` are dense (rows × K) tiles streamed contiguously (packed by
construction), and the irregular part — gathering ``x`` by column index —
runs on-chip against an x panel resident in VMEM.  The element:index ratio
cost of the paper (§III-E) shows up here exactly: each nonzero moves one
``vals`` element *and* one ``cols`` index, so with 32-bit values and 32-bit
indices the useful-data fraction of the stream is r/(r+1) = 50 %.

Two variants:

* ``spmv_ell_kernel``       — x fully VMEM-resident (paper-scale matrices).
* ``spmv_ell_panel_kernel`` — x streamed in column panels for large n; cols
  must be panel-sorted (BCSR-style), the panel id per (row-block, step) is
  scalar-prefetched — an indirect stream descriptor driving the x DMAs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_body(vals_ref, cols_ref, x_ref, y_ref):
    x = x_ref[...].reshape(-1)
    cols = cols_ref[...]
    xg = jnp.take(x, cols, axis=0, mode="clip")  # in-VMEM indirect gather
    y_ref[...] = jnp.sum(vals_ref[...] * xg, axis=1, keepdims=True)


def spmv_ell_kernel(
    vals: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    row_block: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """y = A @ x with A in padded-ELL form; x resident in VMEM.

    vals/cols: (R, K); x: (C,); returns y: (R,).
    """
    r, k = vals.shape
    (c,) = x.shape
    assert r % row_block == 0
    x2 = x.reshape(1, c)
    y = pl.pallas_call(
        _spmv_body,
        grid=(r // row_block,),
        in_specs=[
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
            pl.BlockSpec((row_block, k), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), vals.dtype),
        interpret=interpret,
    )(vals, cols, x2)
    return y.reshape(r)


def _spmv_panel_body(panel_ref, vals_ref, cols_ref, x_ref, y_ref, *, panel: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    base = panel_ref[pl.program_id(0), s] * panel
    x = x_ref[...].reshape(-1)
    local = cols_ref[...] - base  # panel-local column offsets
    valid = (local >= 0) & (local < panel)
    xg = jnp.take(x, jnp.clip(local, 0, panel - 1), axis=0, mode="clip")
    contrib = jnp.where(valid, vals_ref[...] * xg, 0.0)
    y_ref[...] += jnp.sum(contrib, axis=1, keepdims=True)


def spmv_ell_panel_kernel(
    vals: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    panel_ids: jax.Array,
    panel: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Panel-streamed SpMV: x arrives in VMEM one ``panel`` at a time.

    ``panel_ids`` (row_blocks, steps) int32 — which x panel each step of each
    row block needs (scalar-prefetched; the indirect stream descriptor).
    ``cols`` must be sorted so that step s of row block rb only references
    columns inside panel ``panel_ids[rb, s]`` — entries outside are masked.
    """
    r, k = vals.shape
    (c,) = x.shape
    row_blocks, steps = panel_ids.shape
    row_block = r // row_blocks
    assert k % steps == 0
    kb = k // steps
    x2 = x.reshape(c // panel, panel)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(row_blocks, steps),
        in_specs=[
            pl.BlockSpec((row_block, kb), lambda rb, s, p: (rb, s)),
            pl.BlockSpec((row_block, kb), lambda rb, s, p: (rb, s)),
            pl.BlockSpec((1, panel), lambda rb, s, p: (p[rb, s], 0)),
        ],
        out_specs=pl.BlockSpec((row_block, 1), lambda rb, s, p: (rb, 0)),
    )
    y = pl.pallas_call(
        functools.partial(_spmv_panel_body, panel=panel),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, 1), vals.dtype),
        interpret=interpret,
    )(panel_ids, vals, cols, x2)
    return y.reshape(r)
