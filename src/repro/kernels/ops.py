"""Public jit'd wrappers around the Pallas kernels.

Every op takes ``impl`` ∈ {'pallas', 'ref'}:

* ``'pallas'`` — the TPU kernel (``interpret=True`` automatically on CPU, so
  the same call validates on this container and compiles on real TPUs);
* ``'ref'``    — the pure-jnp oracle (differentiable; used for training paths
  that need gradients and as the allclose ground truth).

Wrappers own all the unglamorous parts: padding counts to pack granularity,
padding rows to lane width, and undoing both on the way out — mirroring how
an AXI-Pack requestor aligns bursts to the bus rather than to addresses.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .stream_converters import (
    DEFAULT_PACK_ROWS,
    indirect_gather_kernel,
    indirect_scatter_kernel,
    strided_gather_kernel,
    strided_scatter_kernel,
)
from .transpose import transpose_kernel
from .spmv import spmv_ell_kernel
from .flash_attention import flash_attention_kernel
from .paged_decode import paged_decode_attention_kernel
from .paged_prefill import paged_prefill_attention_kernel
from .paged_verify import paged_verify_attention_kernel

__all__ = [
    "on_cpu",
    "strided_gather",
    "strided_scatter",
    "recurrent_state_read",
    "recurrent_state_write",
    "indirect_gather",
    "indirect_scatter",
    "tiled_transpose",
    "spmv_ell",
    "flash_attention",
    "paged_decode_attention",
    "paged_prefill_attention",
    "paged_verify",
    "speculative_accept",
    "paged_kv_append",
    "paged_kv_write_chunk",
    "moe_dispatch",
    "moe_combine",
]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _interpret() -> bool:
    # Pallas TPU kernels run through the interpreter on CPU hosts.
    return on_cpu()


def _pad_rows(x: jax.Array, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


# ---------------------------------------------------------------------------
# Stream converters
# ---------------------------------------------------------------------------


def strided_gather(
    src: jax.Array, base: int, stride: int, count: int, impl: str = "pallas"
) -> jax.Array:
    """out[k] = src[base + k*stride] — packed strided read."""
    if impl == "ref" or stride == 1:
        # stride==1 → the base (contiguous) converter: plain dynamic slice.
        return ref.strided_gather(src, base, stride, count)
    padded = count + ((-count) % DEFAULT_PACK_ROWS)
    # Keep padded reads in-bounds by clamping the stream tail.
    need = base + (padded - 1) * stride + 1
    if need > src.shape[0]:
        src = jnp.pad(src, [(0, need - src.shape[0])] + [(0, 0)] * (src.ndim - 1))
    out = strided_gather_kernel(src, base, stride, padded, interpret=_interpret())
    return out[:count]


def strided_scatter(
    dst: jax.Array, packed: jax.Array, base: int, stride: int, impl: str = "pallas"
) -> jax.Array:
    """dst[base + k*stride] = packed[k] — packed strided write."""
    if impl == "ref" or stride == 1:
        return ref.strided_scatter(dst, packed, base, stride)
    count = packed.shape[0]
    if count % DEFAULT_PACK_ROWS:
        # Tail rows are written via the ref path to avoid out-of-bounds DMAs.
        main = count - count % DEFAULT_PACK_ROWS
        dst = strided_scatter(dst, packed[:main], base, stride, impl) if main else dst
        return ref.strided_scatter(
            dst, packed[main:], base + main * stride, stride
        )
    return strided_scatter_kernel(dst, packed, base, stride, interpret=_interpret())


def _flat_state_view(pool: jax.Array) -> Tuple[jax.Array, int, int, int]:
    """(L, B, *row) state pool → ((L·B, row) view, L, B, row_width)."""
    l, b = int(pool.shape[0]), int(pool.shape[1])
    row = int(np.prod(pool.shape[2:])) if pool.ndim > 2 else 1
    return pool.reshape(l * b, row), l, b, row


def recurrent_state_read(pool: jax.Array, slot: int, impl: str = "pallas") -> jax.Array:
    """Gather one sequence's recurrent state rows from an (L, B, *row) pool.

    Slot ``s`` of a layer-major pool is rows ``s, s+B, s+2B, ...`` of the
    flat (L·B, row) view — exactly a strided burst with base=slot, stride=B,
    count=L, which is the access the strided PACK converter accelerates.
    """
    if impl == "ref":
        return ref.recurrent_state_read(pool, slot)
    flat, l, b, row = _flat_state_view(pool)
    pad = (-row) % 128  # strided converter packs ≥128-lane rows
    if pad:
        flat = jnp.pad(flat, [(0, 0), (0, pad)])
    out = strided_gather(flat, int(slot), b, l, impl=impl)
    return out[:, :row].reshape((l,) + pool.shape[2:])


def recurrent_state_write(
    pool: jax.Array, slot: int, value: jax.Array, impl: str = "pallas"
) -> jax.Array:
    """Scatter one sequence's state rows back into an (L, B, *row) pool —
    the write half of the strided read-modify-write each decode step does."""
    if impl == "ref":
        return ref.recurrent_state_write(pool, slot, value)
    flat, l, b, row = _flat_state_view(pool)
    vflat = value.reshape(l, row)
    pad = (-row) % 128
    if pad:
        flat = jnp.pad(flat, [(0, 0), (0, pad)])
        vflat = jnp.pad(vflat, [(0, 0), (0, pad)])
    out = strided_scatter(flat, vflat, int(slot), b, impl=impl)
    return out[:, :row].reshape(pool.shape)


def indirect_gather(
    src: jax.Array, indices: jax.Array, impl: str = "pallas"
) -> jax.Array:
    """out[k] = src[indices[k]] — packed indirect read (in-memory indices)."""
    if impl == "ref":
        return ref.indirect_gather(src, indices)
    idx, count = _pad_rows(indices.astype(jnp.int32), DEFAULT_PACK_ROWS)
    out = indirect_gather_kernel(src, idx, interpret=_interpret())
    return out[:count]


def indirect_scatter(
    dst: jax.Array,
    packed: jax.Array,
    indices: jax.Array,
    mode: str = "set",
    impl: str = "pallas",
) -> jax.Array:
    """dst[indices[k]] = packed[k] — packed indirect write."""
    if impl == "ref" or mode == "add":
        # Accumulating scatter needs read-modify-write; route to ref.
        return ref.indirect_scatter(dst, packed, indices, mode)
    count = packed.shape[0]
    pad = (-count) % DEFAULT_PACK_ROWS
    if pad:
        # Padded slots self-scatter row `indices[-1]`'s current value — route
        # them to a scratch row appended to dst, then drop it.
        dst_ext = jnp.pad(dst, [(0, 1)] + [(0, 0)] * (dst.ndim - 1))
        packed_p = jnp.pad(packed, [(0, pad)] + [(0, 0)] * (packed.ndim - 1))
        idx_p = jnp.concatenate(
            [indices.astype(jnp.int32), jnp.full((pad,), dst.shape[0], jnp.int32)]
        )
        out = indirect_scatter_kernel(dst_ext, packed_p, idx_p, interpret=_interpret())
        return out[:-1]
    return indirect_scatter_kernel(
        dst, packed, indices.astype(jnp.int32), interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# Workload kernels
# ---------------------------------------------------------------------------


def tiled_transpose(x: jax.Array, block: int = 128, impl: str = "pallas") -> jax.Array:
    if impl == "ref":
        return ref.tiled_transpose(x)
    r, c = x.shape
    block = min(block, r, c)
    pr, pc = (-r) % block, (-c) % block
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    out = transpose_kernel(x, block=block, interpret=_interpret())
    return out[:c, :r]


def spmv_ell(
    vals: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    row_block: int = 8,
    impl: str = "pallas",
) -> jax.Array:
    if impl == "ref":
        return ref.spmv_ell(vals, cols, x)
    (vals_p, r) = _pad_rows(vals, row_block)
    (cols_p, _) = _pad_rows(cols, row_block)
    y = spmv_ell_kernel(vals_p, cols_p, x, row_block=row_block, interpret=_interpret())
    return y[:r]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    impl: str = "pallas",
) -> jax.Array:
    """Flash attention.  impl='pallas' is fully trainable: the backward is
    the FlashAttention-2-style kernel pair (custom_vjp; lse saved, p
    recomputed blockwise — validated against autodiff in tests)."""
    if impl == "ref":
        return ref.mha(q, k, v, causal=causal, window=window, scale=scale)
    b, h, sq, d = q.shape
    skv = k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pq, pk = (-sq) % bq, (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = _flash_vjp(q, k, v, causal, window, scale, bq, bk)
    # NB: padded KV columns are masked via kv_len inside the kernel.
    return out[:, :, :sq, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, window, scale, bq, bk):
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, interpret=_interpret(),
    )


def _flash_vjp_fwd(q, k, v, causal, window, scale, bq, bk):
    from .flash_attention import flash_attention_fwd_kernel

    o, lse = flash_attention_fwd_kernel(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, interpret=_interpret(),
    )
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(causal, window, scale, bq, bk, res, do):
    from .flash_attention import flash_attention_bwd_kernel

    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd_kernel(
        q, k, v, o, lse, do, causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, interpret=_interpret(),
    )
    return dq, dk, dv


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def paged_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    impl: str = "pallas",
) -> jax.Array:
    """Decode one token per sequence against the paged pool.

    ``k_scale``/``v_scale`` opt into the int8 pool layout: pages hold int8
    codes and each ``(P, page, KVH)`` scale pool holds one fp32 scale per
    page token slot per KV head (``ref.quantize_kv`` on the write side).
    ``impl='pallas'`` dequantizes page-by-page in VMEM; ``impl='ref'``
    dequantizes the whole pool up front through the shared
    :func:`repro.kernels.ref.dequantize_pages` broadcast rule and runs the
    full-precision oracle.
    """
    if impl == "ref":
        if k_scale is not None:
            k_pages = ref.dequantize_pages(k_pages, k_scale)
            v_pages = ref.dequantize_pages(v_pages, v_scale)
        return ref.paged_decode_attention(
            q, k_pages, v_pages, page_table, lengths, scale=scale
        )
    return paged_decode_attention_kernel(
        q, k_pages, v_pages, page_table, lengths,
        k_scale=k_scale, v_scale=v_scale, scale=scale, interpret=_interpret(),
    )


def paged_prefill_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    ctx_rows: jax.Array,
    starts: jax.Array,
    counts: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    impl: str = "pallas",
) -> jax.Array:
    """Causal chunk attention for batched prefill, straight from the pool.

    ``impl='pallas'`` streams each row's context pages through the scalar-
    prefetch indirect path with an online softmax (no gathered context or
    dense score tensor in HBM; GQA grouped in-kernel); ``impl='ref'`` is the
    dense gather + einsum oracle (the pre-kernel serving path).  Rows with
    ``counts == 0`` produce zeros under both.

    ``k_scale``/``v_scale`` opt into the int8 pool layout (same contract as
    :func:`paged_decode_attention`): ``(P, page, KVH)`` fp32 scale pools, one
    scale per page token slot per KV head.  The kernel dequantizes each
    context page in VMEM right after its DMA (fp32 accumulation, identical
    online-softmax structure); the ref path dequantizes the whole pool
    through the shared :func:`repro.kernels.ref.dequantize_pages` rule.
    """
    if impl == "ref":
        if k_scale is not None:
            k_pages = ref.dequantize_pages(k_pages, k_scale)
            v_pages = ref.dequantize_pages(v_pages, v_scale)
        return ref.paged_prefill_attention(
            q, k_pages, v_pages, ctx_rows, starts, counts, scale=scale
        )
    return paged_prefill_attention_kernel(
        q, k_pages, v_pages, ctx_rows, starts, counts,
        k_scale=k_scale, v_scale=v_scale, scale=scale,
        interpret=_interpret(),
    )


def paged_verify(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    ctx_rows: jax.Array,
    lengths: jax.Array,
    counts: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    impl: str = "pallas",
) -> jax.Array:
    """Score K speculative tokens per sequence in one clamped page walk.

    q:       (B, K, H, D) verify queries — the feed token at position 0,
             draft tokens after it; query ``i`` of row ``r`` sits at
             absolute position ``lengths[r] + i``
    counts:  (B,) valid queries per row (0..K); 0 = padding row, zero out

    The speculative-decoding verify step: K causal queries amortize one
    indirect page walk that plain decode would repeat K times.  A verify
    chunk is a prefill chunk appended at the context tail, so both impls
    share the prefill code paths with ``starts = lengths`` (the Pallas
    kernel reuses the clamped scalar-prefetch walk + online softmax; the
    oracle the dense gather + einsum), and ``k_scale``/``v_scale`` opt
    into the same int8 pool layout.  Acceptance is separate — see
    :func:`speculative_accept`.
    """
    if impl == "ref":
        if k_scale is not None:
            k_pages = ref.dequantize_pages(k_pages, k_scale)
            v_pages = ref.dequantize_pages(v_pages, v_scale)
        return ref.paged_verify_attention(
            q, k_pages, v_pages, ctx_rows, lengths, counts, scale=scale
        )
    return paged_verify_attention_kernel(
        q, k_pages, v_pages, ctx_rows, lengths, counts,
        k_scale=k_scale, v_scale=v_scale, scale=scale,
        interpret=_interpret(),
    )


def speculative_accept(
    drafts: jax.Array, greedy: jax.Array, counts: jax.Array
) -> jax.Array:
    """Greedy first-mismatch acceptance: how many verify tokens to emit.

    Pure jnp (no kernel needed — it's O(B·K) int math) and shared by both
    impls so accept/reject stays on device; see
    :func:`repro.kernels.ref.speculative_accept` for the contract.
    """
    return ref.speculative_accept(drafts, greedy, counts)


def paged_kv_append(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    active: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    impl: str = "pallas",
):
    """Append one KV token per sequence into the paged pool.

    ``impl='pallas'`` routes the writes through the packed indirect-scatter
    converter kernel over the row-flattened pool (one indirect write burst
    per K and V); ``impl='ref'`` is the plain XLA scatter oracle.  Both drop
    inactive sequences by routing their index out of bounds.

    Passing ``k_scale``/``v_scale`` — the ``(P, page, KVH)`` fp32 scale pools
    of an int8 KV pool — turns this into *quantize-on-write*: the new rows
    are quantized per (token, kv-head) over ``D`` (``ref.quantize_kv``), the
    int8 codes scatter into the pages and the scales into the scale pools
    through the **same** flat indices (one extra narrow indirect burst per
    pool — the AXI-Pack picture: the value stream plus its sideband metadata
    share one descriptor).  Returns ``(k_pages, v_pages, new_lengths)``,
    plus ``(k_scale, v_scale)`` appended when quantizing.
    """
    if impl == "ref":
        return ref.paged_kv_append(
            k_pages, v_pages, k_new, v_new, page_table, lengths, active,
            k_scale=k_scale, v_scale=v_scale,
        )
    p, page, kvh, d = k_pages.shape
    quantized = k_scale is not None
    if quantized:
        k_new, k_s = ref.quantize_kv(k_new)
        v_new, v_s = ref.quantize_kv(v_new)
    slot = lengths // page
    off = lengths % page
    n_pages = page_table.shape[1]
    pids = jnp.take_along_axis(
        page_table, jnp.clip(slot, 0, n_pages - 1)[:, None], axis=1
    )[:, 0]
    flat_idx = (pids * page + off).astype(jnp.int32)
    if active is None:
        active = jnp.ones_like(lengths, dtype=bool)
    # Inactive rows — and rows whose append position falls past their table
    # row (the oracle's ``mode='drop'`` case: an un-clamped out-of-bounds
    # gather would otherwise alias a real page) — target the scratch row
    # appended below, then get dropped.
    flat_idx = jnp.where(
        active & (lengths < n_pages * page), flat_idx, p * page
    )

    def write(pool, new, width):
        flat = jnp.pad(pool.reshape(p * page, width), ((0, 1), (0, 0)))
        flat = indirect_scatter(flat, new.reshape(-1, width), flat_idx, impl=impl)
        return flat[:-1]

    k_pages = write(k_pages, k_new, kvh * d).reshape(p, page, kvh, d)
    v_pages = write(v_pages, v_new, kvh * d).reshape(p, page, kvh, d)
    new_len = lengths + active.astype(lengths.dtype)
    if quantized:
        k_scale = write(k_scale, k_s, kvh).reshape(p, page, kvh)
        v_scale = write(v_scale, v_s, kvh).reshape(p, page, kvh)
        return k_pages, v_pages, new_len, k_scale, v_scale
    return k_pages, v_pages, new_len


def paged_kv_write_chunk(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    rows: jax.Array,
    starts: jax.Array,
    counts: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    impl: str = "pallas",
):
    """Batched chunked-prefill write, bounded by the pages the chunk touches.

    ``impl='ref'`` is the full-pool scatter oracle.  ``impl='pallas'`` never
    materializes an O(pool) intermediate: each sequence's chunk spans at most
    ``W = ceil(C/page) + 1`` pages, so the converter path gathers those W
    pages per sequence (one packed indirect read), scatters the chunk's rows
    into the gathered window, and writes the touched pages back (one packed
    indirect write) — R·W pages of traffic instead of the whole pool.
    Window slots that cover no valid token are routed out of bounds on the
    way back so a stale copy can never clobber another sequence's page.

    Passing ``k_scale``/``v_scale`` — ``(P, page, KVH)`` fp32 scale pools —
    turns this into *quantize-on-write* (same contract as
    :func:`paged_kv_append`): the chunk is quantized per (token, kv-head)
    over ``D``, the int8 codes go through the window gather/scatter above
    and the scales through an identical (narrower) window walk over the
    scale pools — same page ids, same local indices, same out-of-bounds
    routing.  Returns ``(k_pages, v_pages)``, plus ``(k_scale, v_scale)``
    appended when quantizing.
    """
    if impl == "ref":
        return ref.paged_kv_write_chunk(
            k_pages, v_pages, k_new, v_new, rows, starts, counts,
            k_scale=k_scale, v_scale=v_scale,
        )
    p, page, kvh, d = k_pages.shape
    r, c = k_new.shape[:2]
    n_pages = rows.shape[1]
    quantized = k_scale is not None
    if quantized:
        k_new, k_s = ref.quantize_kv(k_new)
        v_new, v_s = ref.quantize_kv(v_new)
    w = -(-c // page) + 1
    p_lo = starts // page                                         # (R,)
    lp = p_lo[:, None] + jnp.arange(w, dtype=jnp.int32)           # (R, W)
    pids = jnp.take_along_axis(
        rows, jnp.clip(lp, 0, n_pages - 1), axis=1
    )                                                             # (R, W)
    # A window slot is real iff it covers >= 1 valid token of its sequence.
    p_hi = (starts + jnp.maximum(counts - 1, 0)) // page
    real = (lp <= p_hi[:, None]) & (counts[:, None] > 0) & (lp < n_pages)
    # Local scatter index of token (r, c) inside the (R, W, page) window.
    pos = starts[:, None] + jnp.arange(c, dtype=jnp.int32)        # (R, C)
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < counts[:, None]
    wp = pos // page - p_lo[:, None]                              # (R, C)
    loc = (jnp.arange(r, dtype=jnp.int32)[:, None] * w + wp) * page + pos % page
    loc = jnp.where(valid, loc, r * w * page).reshape(-1)

    def write(pool, new, width):
        flat = pool.reshape(p, page * width)
        win = indirect_gather(
            flat, jnp.clip(pids, 0, p - 1).reshape(-1), impl=impl
        )                                                         # (R*W, ...)
        win = jnp.pad(
            win.reshape(r * w * page, width), ((0, 1), (0, 0))
        )
        win = indirect_scatter(win, new.reshape(-1, width), loc, impl=impl)
        win = win[:-1].reshape(r * w, page * width)
        out = jnp.pad(flat, ((0, 1), (0, 0)))
        out = indirect_scatter(
            out, win, jnp.where(real, pids, p).reshape(-1), impl=impl
        )
        return out[:-1]

    kp = write(k_pages, k_new, kvh * d).reshape(p, page, kvh, d)
    vp = write(v_pages, v_new, kvh * d).reshape(p, page, kvh, d)
    if quantized:
        ks = write(k_scale, k_s, kvh).reshape(p, page, kvh)
        vs = write(v_scale, v_s, kvh).reshape(p, page, kvh)
        return kp, vp, ks, vs
    return kp, vp


# ---------------------------------------------------------------------------
# MoE packed dispatch / combine (composite over the indirect converters)
# ---------------------------------------------------------------------------


def moe_dispatch(
    tokens: jax.Array,
    expert_idx: jax.Array,
    num_experts: int,
    capacity: int,
    impl: str = "pallas",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pack tokens into (E, C, D) expert buffers via an indirect stream.

    Slot computation (ranking within expert) is cheap int arithmetic; the
    heavy data movement — scattering token rows into expert-contiguous
    buffers — is one packed indirect write.
    """
    if impl == "ref":
        return ref.moe_dispatch(tokens, expert_idx, num_experts, capacity)
    t, d = tokens.shape
    k = expert_idx.shape[1]
    flat_e = expert_idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    pos_in_e = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot - onehot, axis=1)
    keep = pos_in_e < capacity
    slot = jnp.where(keep, flat_e * capacity + pos_in_e, num_experts * capacity)

    tok_rep = jnp.repeat(tokens, k, axis=0)
    buf = jnp.zeros((num_experts * capacity + 1, d), tokens.dtype)
    buf = indirect_scatter(buf, tok_rep, slot, impl=impl)[:-1]

    src = jnp.full((num_experts * capacity + 1,), -1, jnp.int32)
    src = src.at[slot].set(jnp.arange(t * k, dtype=jnp.int32))[:-1]
    return (
        buf.reshape(num_experts, capacity, d),
        src.reshape(num_experts, capacity),
        keep.reshape(t, k),
    )


def moe_combine(
    outputs: jax.Array,
    src_index: jax.Array,
    gate_weights: jax.Array,
    num_tokens: int,
    impl: str = "pallas",
) -> jax.Array:
    """Un-pack expert outputs to token order (indirect gather) + gate-weight."""
    if impl == "ref":
        return ref.moe_combine(outputs, src_index, gate_weights, num_tokens)
    e, c, d = outputs.shape
    k = gate_weights.shape[1]
    flat_out = outputs.reshape(e * c, d)
    flat_src = src_index.reshape(e * c)
    # Invert the dispatch permutation: for each (token, k) slot find its
    # expert-buffer position, then gather — one packed indirect read.
    inv = jnp.full((num_tokens * k + 1,), e * c, jnp.int32)
    inv = inv.at[jnp.where(flat_src >= 0, flat_src, num_tokens * k)].set(
        jnp.arange(e * c, dtype=jnp.int32)
    )[:-1]
    flat_out_ext = jnp.pad(flat_out, ((0, 1), (0, 0)))  # row e*c = zeros (dropped)
    contrib = indirect_gather(flat_out_ext, inv, impl=impl)
    contrib = contrib.reshape(num_tokens, k, d)
    return jnp.einsum("tkd,tk->td", contrib, gate_weights.astype(outputs.dtype))
