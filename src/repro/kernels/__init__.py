"""Pallas TPU kernels for the packed-stream hot spots.

Layout per kernel: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec
tiling; :mod:`repro.kernels.ops` the jit'd public wrappers with impl
dispatch; :mod:`repro.kernels.ref` the pure-jnp oracles.
"""
from . import ops, ref
from .ops import (
    flash_attention,
    indirect_gather,
    indirect_scatter,
    moe_combine,
    moe_dispatch,
    paged_decode_attention,
    paged_prefill_attention,
    paged_verify,
    speculative_accept,
    spmv_ell,
    strided_gather,
    strided_scatter,
    tiled_transpose,
)
