"""Flash attention forward kernel (training/prefill path).

Streaming softmax over KV blocks with running (m, l, acc) VMEM scratch —
O(S) memory at any sequence length, which is what makes the 32k prefill and
500k decode shapes lowerable.  Supports causal masking, gemma3-style sliding
windows (a *strided/banded* access pattern: each query block touches only a
window-limited band of KV blocks, skipped entirely via ``pl.when`` when out
of range) and GQA (KV head selected by the BlockSpec ``index_map`` — the
group mapping never materializes repeated KV in HBM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # finite sentinel: keeps exp() NaN-free on fully-masked rows


def _flash_body(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    causal: bool,
    window: Optional[int],
    bq: int,
    bk: int,
    scale: float,
    num_kv_blocks: int,
    kv_len: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    first_q = i * bq
    last_q = first_q + bq - 1
    first_k = j * bk
    last_k = first_k + bk - 1

    visible = jnp.bool_(True)
    if causal:
        visible &= last_q >= first_k
    if window is not None:
        visible &= first_q - last_k < window

    @pl.when(visible)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                          # (bq, bk)
        qi = first_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = first_k + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kj < kv_len
        if causal:
            mask &= qi >= kj
        if window is not None:
            mask &= qi - kj < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                              # (bq, 1)
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _flash_body_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, **kw):
    """Forward body that additionally emits log-sum-exp rows (for backward)."""
    _flash_body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, **kw)
    j = pl.program_id(3)

    @pl.when(j == kw["num_kv_blocks"] - 1)
    def _emit():
        l = jnp.where(l_ref[:, :1] == 0.0, 1.0, l_ref[:, :1])
        lse_ref[0, 0] = (m_ref[:, :1] + jnp.log(l))[:, 0]


def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Forward flash attention. q (B,H,Sq,D); k,v (B,KVH,Skv,D) → (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    assert h % kvh == 0
    rep = h // kvh
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, "wrapper must pad seq lens"
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    grid = (b, h, sq // bq, skv // bk)
    body = functools.partial(
        _flash_body,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        scale=scale,
        num_kv_blocks=skv // bk,
        kv_len=skv,
    )
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_fwd_kernel(
    q, k, v, causal=True, window=None, scale=None,
    block_q=128, block_k=128, interpret=False,
):
    """Forward returning (o, lse) — the residuals the backward kernels need."""
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    rep = h // kvh
    bq, bk = min(block_q, sq), min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    body = functools.partial(
        _flash_body_lse, causal=causal, window=window, bq=bq, bk=bk,
        scale=scale, num_kv_blocks=skv // bk, kv_len=skv,
    )
    return pl.pallas_call(
        body,
        grid=(b, h, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, i, j: (b_, h_, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward kernels (FlashAttention-2 style, two passes)
# ---------------------------------------------------------------------------


def _mask_block(first_q, first_k, bq, bk, causal, window, window_flag=None):
    qi = first_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = first_k + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= qi - kj < window
    return mask


def _dkv_body(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc, dv_acc, *, causal, window, bq, bk, scale, num_q_blocks, rep,
):
    # grid (B, KVH, Skv/bk, rep, Sq/bq): dk/dv accumulate over (rep, i)
    j = pl.program_id(2)
    r = pl.program_id(3)
    i = pl.program_id(4)

    @pl.when((r == 0) & (i == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    first_q, first_k = i * bq, j * bk
    visible = jnp.bool_(True)
    if causal:
        visible &= first_q + bq - 1 >= first_k
    if window is not None:
        visible &= first_q - (first_k + bk - 1) < window

    @pl.when(visible)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)          # (bq,)
        delta = delta_ref[0, 0].astype(jnp.float32)      # (bq,)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _mask_block(first_q, first_k, bq, bk, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)   # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when((r == rep - 1) & (i == num_q_blocks - 1))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_body(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_acc,
    *, causal, window, bq, bk, scale, num_kv_blocks,
):
    # grid (B, H, Sq/bq, Skv/bk): dq accumulates over j
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    first_q, first_k = i * bq, j * bk
    visible = jnp.bool_(True)
    if causal:
        visible &= first_q + bq - 1 >= first_k
    if window is not None:
        visible &= first_q - (first_k + bk - 1) < window

    @pl.when(visible)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)
        delta = delta_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        mask = _mask_block(first_q, first_k, bq, bk, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def flash_attention_bwd_kernel(
    q, k, v, o, lse, do, causal=True, window=None, scale=None,
    block_q=128, block_k=128, interpret=False,
):
    """FlashAttention-2-style backward: returns (dq, dk, dv).

    dk/dv kernel: grid (B, KVH, Skv/bk, rep, Sq/bq) — each kv-head block
    accumulates over its GQA group and all q blocks (recomputing p from the
    saved lse, never materializing (Sq, Skv)).  dq kernel: grid
    (B, H, Sq/bq, Skv/bk).  delta = rowsum(do·o) precomputed outside.
    """
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    rep = h // kvh
    bq, bk = min(block_q, sq), min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dkv_body = functools.partial(
        _dkv_body, causal=causal, window=window, bq=bq, bk=bk,
        scale=scale, num_q_blocks=sq // bq, rep=rep,
    )
    # q/do/lse/delta blocks walk the GQA group: head = kvh_idx * rep + r
    q_map = lambda b_, g, j, r, i: (b_, g * rep + r, i, 0)
    v_map = lambda b_, g, j, r, i: (b_, g, j, 0)
    s_map = lambda b_, g, j, r, i: (b_, g * rep + r, i)
    dk, dv = pl.pallas_call(
        dkv_body,
        grid=(b, kvh, skv // bk, rep, sq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bk, d), v_map),
            pl.BlockSpec((1, 1, bk, d), v_map),
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bq), s_map),
            pl.BlockSpec((1, 1, bq), s_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b_, g, j, r, i: (b_, g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, g, j, r, i: (b_, g, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dq_body = functools.partial(
        _dq_body, causal=causal, window=window, bq=bq, bk=bk,
        scale=scale, num_kv_blocks=skv // bk,
    )
    dq = pl.pallas_call(
        dq_body,
        grid=(b, h, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // rep, j, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, i, j: (b_, h_, i)),
            pl.BlockSpec((1, 1, bq), lambda b_, h_, i, j: (b_, h_, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
