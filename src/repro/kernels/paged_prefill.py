"""Paged-KV prefill attention kernel — flash-style chunk attention over pages.

The read side of batched chunked prefill, as an indirect packed stream: each
pending sequence's context lives in scattered physical pages, and its page-
table row is the memory-resident index vector of an AXI-Pack indirect burst.
Here (as in :mod:`repro.kernels.paged_decode`) the table rides the scalar-
prefetch channel and the BlockSpec ``index_map`` turns each entry into one
direct HBM→VMEM page DMA — the chunk's queries stream over their context one
page at a time with an online (flash) softmax, so neither the gathered
``(R, ctx·page, KVH, D)`` context nor the ``(R, C, H, ctx·page)`` score
tensor is ever materialized in HBM.  GQA is handled by grouping queries per
KV head inside the kernel (the group mapping never repeats K/V).

The page walk is *length-adaptive* exactly like decode: per-row context page
counts (``ceil((start + count) / page)``) are prefetched, and every grid step
past a row's last context page is clamped to that page — revisited blocks are
not re-fetched, so unmapped tail pages (short rows in a wide-bucket batch)
issue no DMAs, and out-of-context compute is skipped by the
``j·page < ctx_len`` predicate.  ``counts == 0`` padding rows clamp their
entire walk to the row's first table entry — at most one warm-up page fetch
(as with decode's empty sequences), never the tail — and output exact zeros.

Supports int8-quantized pools exactly like decode: per-(page-token,
kv-head) scale pages ride the same clamped index map as their K/V pages
and dequant happens in VMEM before the fp32 accumulation — narrower
elements packed onto the page stream, the paper's §III-E element-size
argument (8-bit elements quadruple the FP32 packing factor).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # finite sentinel: keeps exp() NaN-free on fully-masked rows


def _prefill_body(
    # scalar prefetch
    page_table_ref,   # (R * ctx_pages,) physical page ids
    starts_ref,       # (R,) absolute position of each row's tokens[0]
    counts_ref,       # (R,) valid tokens per row (0 = padding row)
    used_ref,         # (R,) context-page count per row (ceil(ctx_len/page))
    # inputs
    q_ref,            # (1, C, H, D)
    k_ref,            # (1, page, KVH, D) — int8 codes when quantized
    v_ref,
    k_scale_ref,      # (1, page, KVH) fp32 or None
    v_scale_ref,
    # output
    o_ref,            # (1, C, H, D)
    # scratch
    m_ref,            # (C*H, 128) running max
    l_ref,            # (C*H, 128) running denominator
    acc_ref,          # (C*H, D)   running numerator
    *,
    page: int,
    ctx_pages: int,
    c: int,
    kvh: int,
    rep: int,
    d: int,
    scale: float,
    quantized: bool,
):
    r = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = starts_ref[r]
    count = counts_ref[r]
    # Padding rows (count == 0) have a zero context bound regardless of
    # ``start``: every block is skipped and the output stays exact zeros.
    ctx_len = jnp.where(count > 0, start + count, 0)

    @pl.when(j * page < ctx_len)
    def _update():
        k = k_ref[0].astype(jnp.float32)                  # (page, KVH, D)
        v = v_ref[0].astype(jnp.float32)
        if quantized:
            # Dequant in VMEM right after the page DMA (the narrow elements
            # travelled the bus packed; same broadcast as dequantize_pages).
            k = k * k_scale_ref[0].astype(jnp.float32)[..., None]
            v = v * v_scale_ref[0].astype(jnp.float32)[..., None]
        q = q_ref[0].astype(jnp.float32)                  # (C, H, D)
        # Group queries per KV head: row (g, ci*rep + u) is query position ci
        # of head g*rep + u — GQA without materializing repeated K/V.
        qg = (q.reshape(c, kvh, rep, d)
              .transpose(1, 0, 2, 3)
              .reshape(kvh, c * rep, d))
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        ) * scale                                         # (KVH, C*rep, page)
        kv_pos = j * page + jax.lax.broadcasted_iota(
            jnp.int32, (kvh, c * rep, page), 2
        )
        q_pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (kvh, c * rep, page), 1
        ) // rep
        mask = (kv_pos <= q_pos) & (kv_pos < ctx_len)
        s = jnp.where(mask, s, NEG_INF)

        rows = c * kvh * rep                              # == C * H
        s_f = s.reshape(rows, page)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s_f, axis=1, keepdims=True))
        p = jnp.where(mask.reshape(rows, page), jnp.exp(s_f - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True),
            l_ref.shape,
        )
        # acc update: p (KVH, C*rep, page) × v (page, KVH, D) → (KVH, C*rep, D)
        pv = jax.lax.dot_general(
            p.reshape(kvh, c * rep, page), v,
            (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha + pv.reshape(rows, d)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(j == ctx_pages - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        out = (acc_ref[...] / l).reshape(kvh, c, rep, d).transpose(1, 0, 2, 3)
        o_ref[0] = out.reshape(c, kvh * rep, d).astype(o_ref.dtype)


def paged_prefill_attention_kernel(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    ctx_rows: jax.Array,
    starts: jax.Array,
    counts: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Causal chunk attention for one batched prefill step over a paged pool.

    q:          (R, C, H, D)  chunk queries; row r's query ``c`` sits at
                absolute position ``starts[r] + c``
    k/v_pages:  (P, page, KVH, D) physical page pool (the chunk's K/V rows
                must already be written — attention runs after the chunk
                write, as in the serve engine); int8 codes when
                ``k_scale``/``v_scale`` given
    ctx_rows:   (R, ctx_pages) int32 leading page-table entries per row
    starts:     (R,) int32 absolute position of tokens[0]
    counts:     (R,) int32 valid tokens per row; ``counts[r] == 0`` rows are
                padding and produce zero output (compute predicated off, the
                walk clamped to the row's first table entry — at most one
                warm-up page fetch, no NaNs)
    k/v_scale:  optional (P, page, KVH) fp32 scale pools (one scale per page
                token slot per KV head).  Each scale page rides the same
                clamped index map as its K/V page — one extra narrow DMA per
                grid step — and the dequant happens in VMEM before the fp32
                flash accumulation, so the online-softmax structure is
                unchanged.

    Query ``c`` of row ``r`` attends positions ``0 .. starts[r] + c`` capped
    at the row's written context (``starts[r] + counts[r]`` tokens), with an
    online softmax accumulated over one grid step per context page.
    """
    r, c, h, d = q.shape
    _, page, kvh, _ = k_pages.shape
    ctx_pages = ctx_rows.shape[1]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    quantized = k_scale is not None

    flat_table = ctx_rows.reshape(-1).astype(jnp.int32)
    starts = starts.astype(jnp.int32)
    counts = counts.astype(jnp.int32)
    # Padding rows clamp their whole walk to the first table entry (one
    # revisited block, like decode's empty sequences) — no tail DMAs.
    used = jnp.where(
        counts > 0, jnp.maximum(-(-(starts + counts) // page), 1), 1
    ).astype(jnp.int32)

    def table_idx(r_, j, pt_ref, st_ref, ct_ref, used_ref):
        jj = jnp.minimum(j, used_ref[r_] - 1)
        return (pt_ref[r_ * ctx_pages + jj], 0, 0, 0)

    def scale_idx(r_, j, pt_ref, st_ref, ct_ref, used_ref):
        jj = jnp.minimum(j, used_ref[r_] - 1)
        return (pt_ref[r_ * ctx_pages + jj], 0, 0)

    q_idx = lambda r_, j, pt, st, ct, us: (r_, 0, 0, 0)

    in_specs = [
        pl.BlockSpec((1, c, h, d), q_idx),
        pl.BlockSpec((1, page, kvh, d), table_idx),
        pl.BlockSpec((1, page, kvh, d), table_idx),
    ]
    args = [q, k_pages, v_pages]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, page, kvh), scale_idx),
            pl.BlockSpec((1, page, kvh), scale_idx),
        ]
        args += [k_scale, v_scale]

    body = functools.partial(
        _prefill_body,
        page=page,
        ctx_pages=ctx_pages,
        c=c,
        kvh=kvh,
        rep=rep,
        d=d,
        scale=scale,
        quantized=quantized,
    )
    if not quantized:
        body = functools.partial(_drop_scale_refs, body)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(r, ctx_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, c, h, d), q_idx),
        scratch_shapes=[
            pltpu.VMEM((c * h, 128), jnp.float32),
            pltpu.VMEM((c * h, 128), jnp.float32),
            pltpu.VMEM((c * h, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, c, h, d), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(flat_table, starts, counts, used, *args)


def _drop_scale_refs(body, pt, st, ct, us, q_ref, k_ref, v_ref, o_ref, m_ref,
                     l_ref, acc_ref):
    return body(pt, st, ct, us, q_ref, k_ref, v_ref, None, None, o_ref,
                m_ref, l_ref, acc_ref)
