"""Multi-query paged verify attention — K draft tokens per page walk.

Speculative decoding's verify step scores the feed token plus K-1 draft
tokens for every sequence in **one** clamped, scalar-prefetched walk over
that sequence's context pages.  Structurally this is the flash-prefill
kernel (:mod:`repro.kernels.paged_prefill`) with a tiny causal chunk:
row ``r``'s query ``i`` sits at absolute position ``lengths[r] + i`` and
attends everything written up to and including itself, so the chunk body
— clamped index map, online softmax, in-VMEM GQA grouping and int8
dequant — is *identical* to prefill with ``starts = lengths``.  We reuse
``_prefill_body`` directly rather than fork it: the verify kernel is the
prefill kernel at chunk size K, and keeping one body keeps the two paths
bit-identical by construction.

What makes this the speculative *perf* kernel is the amortization: plain
decode walks every context page once per generated token (K narrow
indirect bursts for K tokens), while verify walks them once per K-token
batch — the AXI-Pack packed-indirect-burst argument applied along the
time axis instead of the batch axis.  ``core.packing.spec_verify_traffic``
accounts exactly that saving.

The grid is ``(B, ctx_pages)`` with the per-row walk clamped to
``ceil((lengths[r] + counts[r]) / page)`` pages; rows with
``counts[r] == 0`` (inactive slots, capacity-clamped slots) are padding
rows — their walk clamps to the row's first table entry and they output
exact zeros, never NaNs.  The K query tokens' own K/V rows must already
be appended to the pool (the engine writes the chunk first, exactly as
prefill does).
"""
from __future__ import annotations

from typing import Optional

import jax

from .paged_prefill import paged_prefill_attention_kernel


def paged_verify_attention_kernel(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    ctx_rows: jax.Array,
    lengths: jax.Array,
    counts: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Score K speculative query tokens per sequence in one page walk.

    q:          (B, K, H, D) verify queries — query ``i`` of row ``r`` is
                the token at absolute position ``lengths[r] + i`` (the feed
                token at i=0, drafts after it)
    k/v_pages:  (P, page, KVH, D) physical pool; the K query tokens' K/V
                must already be written (append precedes attention, as in
                prefill); int8 codes when scales are given
    ctx_rows:   (B, ctx_pages) leading page-table entries per row
    lengths:    (B,) tokens already in each row's context *before* this
                verify chunk
    counts:     (B,) valid query tokens per row (0..K; 0 = padding row,
                zero output)
    k/v_scale:  optional (P, page, KVH) fp32 scale pools riding the same
                clamped index map (int8 pools)

    Returns (B, K, H, D) attention outputs.  Bit-identical to
    ``paged_prefill_attention_kernel(q, ..., starts=lengths, counts)`` —
    a verify chunk *is* a causal prefill chunk appended at the context
    tail.
    """
    return paged_prefill_attention_kernel(
        q, k_pages, v_pages, ctx_rows, lengths, counts,
        k_scale=k_scale, v_scale=v_scale, scale=scale, interpret=interpret,
    )
