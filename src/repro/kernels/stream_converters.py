"""Pallas TPU kernels for the four packed irregular-stream converters.

These are the TPU-native forms of the paper's controller datapaths (Fig. 2):

* ``strided_gather_kernel``  — strided read converter: rows at
  ``base + k*stride`` are fetched by per-row DMAs whose addresses come from a
  *static* stride in the BlockSpec ``index_map`` (no index traffic at all,
  like the stride field of an AXI-Pack AR request), and packed densely into
  bus-aligned (``pack_rows`` × row) VMEM tiles by the beat-packer pattern
  (an output block revisited across grid steps).
* ``strided_scatter_kernel`` — strided write converter (beat unpacker).
* ``indirect_gather_kernel`` — indirect read converter: the index array is
  **scalar-prefetched into SMEM** and consumed by the ``index_map``, so the
  DMA engine itself resolves the indirection near memory — the Pallas
  equivalent of the paper's index stage feeding the element request
  generator.  The compute core only ever sees packed dense tiles.
* ``indirect_scatter_kernel`` — indirect write converter (aliased output so
  untouched destination rows are preserved; duplicate indices are
  last-writer-wins in grid order, matching the unspecified-order hardware
  semantics).

Hardware-adaptation note: AXI-Pack packs at *word* (32-bit) granularity
because its banked endpoint has 32-bit banks.  HBM has no word-granular
access — the efficient granule is a ~512 B transaction — so the TPU-native
stream granule is a **row** (≥128 lanes).  Element-granular strided access is
provided by the models/benchmarks at tile level (e.g. ismt works on (8,128)
tiles); see DESIGN.md §2.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_PACK_ROWS = 8  # rows per packed VMEM tile (f32 sublane count)


# ---------------------------------------------------------------------------
# Strided read converter
# ---------------------------------------------------------------------------


def _strided_gather_body(src_ref, out_ref, *, pack_rows: int):
    i = pl.program_id(0)
    out_ref[pl.ds(i % pack_rows, 1), :] = src_ref[...]


def strided_gather_kernel(
    src: jax.Array,
    base: int,
    stride: int,
    count: int,
    pack_rows: int = DEFAULT_PACK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Gather ``count`` rows at ``base + k*stride`` into a packed (count, row) block.

    ``base``/``stride`` are static, mirroring the AR user field of a strided
    AXI-Pack burst: the request fully describes the stream, no index memory
    traffic is issued.
    """
    n_rows, row_w = src.shape
    assert count % pack_rows == 0, "wrapper must pad count to pack_rows"
    grid = (count,)
    return pl.pallas_call(
        functools.partial(_strided_gather_body, pack_rows=pack_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, row_w), lambda i: (base + i * stride, 0)),
        ],
        out_specs=pl.BlockSpec((pack_rows, row_w), lambda i: (i // pack_rows, 0)),
        out_shape=jax.ShapeDtypeStruct((count, row_w), src.dtype),
        interpret=interpret,
    )(src)


# ---------------------------------------------------------------------------
# Strided write converter
# ---------------------------------------------------------------------------


def _strided_scatter_body(packed_ref, dst_ref, out_ref, *, pack_rows: int):
    i = pl.program_id(0)
    out_ref[...] = packed_ref[pl.ds(i % pack_rows, 1), :]


def strided_scatter_kernel(
    dst: jax.Array,
    packed: jax.Array,
    base: int,
    stride: int,
    pack_rows: int = DEFAULT_PACK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Scatter packed rows to ``dst[base + k*stride]`` (beat unpacker)."""
    count, row_w = packed.shape
    assert count % pack_rows == 0
    return pl.pallas_call(
        functools.partial(_strided_scatter_body, pack_rows=pack_rows),
        grid=(count,),
        in_specs=[
            pl.BlockSpec((pack_rows, row_w), lambda i: (i // pack_rows, 0)),
            pl.BlockSpec((1, row_w), lambda i: (0, 0)),  # alias anchor only
        ],
        out_specs=pl.BlockSpec((1, row_w), lambda i: (base + i * stride, 0)),
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(packed, dst)


# ---------------------------------------------------------------------------
# Indirect read converter (scalar-prefetched index stage)
# ---------------------------------------------------------------------------


def _indirect_gather_body(idx_ref, src_ref, out_ref, *, pack_rows: int):
    i = pl.program_id(0)
    out_ref[pl.ds(i % pack_rows, 1), :] = src_ref[...]


def indirect_gather_kernel(
    src: jax.Array,
    indices: jax.Array,
    pack_rows: int = DEFAULT_PACK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Gather rows ``src[indices[k]]`` into a packed block.

    The index array rides the scalar-prefetch channel (SMEM) and is consumed
    by the BlockSpec ``index_map`` — the element DMAs are issued directly
    from the indices without the data ever detouring through the core, the
    exact analogue of memory-side indirection (``vlimxei``).
    """
    n_rows, row_w = src.shape
    (count,) = indices.shape
    assert count % pack_rows == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(count,),
        in_specs=[
            pl.BlockSpec((1, row_w), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec(
            (pack_rows, row_w), lambda i, idx_ref: (i // pack_rows, 0)
        ),
    )
    return pl.pallas_call(
        functools.partial(_indirect_gather_body, pack_rows=pack_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((count, row_w), src.dtype),
        interpret=interpret,
    )(indices, src)


# ---------------------------------------------------------------------------
# Indirect write converter
# ---------------------------------------------------------------------------


def _indirect_scatter_body(idx_ref, packed_ref, dst_ref, out_ref, *, pack_rows: int):
    i = pl.program_id(0)
    out_ref[...] = packed_ref[pl.ds(i % pack_rows, 1), :]


def indirect_scatter_kernel(
    dst: jax.Array,
    packed: jax.Array,
    indices: jax.Array,
    pack_rows: int = DEFAULT_PACK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Scatter packed rows to ``dst[indices[k]]``; untouched rows preserved.

    Duplicate indices resolve last-writer-wins in grid order (hardware leaves
    the order unspecified; callers needing accumulation use the ``ref`` add
    path or MoE combine).
    """
    count, row_w = packed.shape
    assert count % pack_rows == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(count,),
        in_specs=[
            pl.BlockSpec((pack_rows, row_w), lambda i, idx_ref: (i // pack_rows, 0)),
            pl.BlockSpec((1, row_w), lambda i, idx_ref: (0, 0)),  # alias anchor
        ],
        out_specs=pl.BlockSpec((1, row_w), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        functools.partial(_indirect_scatter_body, pack_rows=pack_rows),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(indices, packed, dst)
