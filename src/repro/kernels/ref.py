"""Pure-jnp oracle implementations for every Pallas kernel in this package.

Each function is the mathematical ground truth the kernels are validated
against (``tests/kernels`` sweeps shapes/dtypes and asserts allclose).  They
are also the CPU fall-back path used by the models when
``use_pallas=False`` and the source of the differentiable reference
semantics (kernels that need gradients wire these in through custom_vjp or
are used forward-only).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Packed stream converters (the paper's four irregular converters)
# ---------------------------------------------------------------------------


def strided_gather(src: jax.Array, base: int, stride: int, count: int) -> jax.Array:
    """out[k] = src[base + k*stride]; src (N, row), out (count, row)."""
    idx = base + stride * jnp.arange(count)
    return jnp.take(src, idx, axis=0, mode="clip")


def strided_scatter(
    dst: jax.Array, packed: jax.Array, base: int, stride: int
) -> jax.Array:
    """dst[base + k*stride] = packed[k]."""
    idx = base + stride * jnp.arange(packed.shape[0])
    return dst.at[idx].set(packed)


def recurrent_state_read(pool: jax.Array, slot: int) -> jax.Array:
    """out[l] = pool[l, slot] — one sequence's state rows from (L, B, *row)."""
    return pool[:, slot]


def recurrent_state_write(pool: jax.Array, slot: int, value: jax.Array) -> jax.Array:
    """pool[l, slot] = value[l] — write-back half of the state RMW."""
    return pool.at[:, slot].set(value)


def indirect_gather(src: jax.Array, indices: jax.Array) -> jax.Array:
    """out[k] = src[indices[k]]; indices memory-resident (vlimxei semantics)."""
    return jnp.take(src, indices, axis=0, mode="clip")


def indirect_scatter(
    dst: jax.Array, packed: jax.Array, indices: jax.Array, mode: str = "set"
) -> jax.Array:
    """dst[indices[k]] = packed[k] (or += for mode='add')."""
    at = dst.at[indices]
    return at.add(packed) if mode == "add" else at.set(packed)


# ---------------------------------------------------------------------------
# Tiled in-situ matrix transpose (ismt benchmark)
# ---------------------------------------------------------------------------


def tiled_transpose(x: jax.Array) -> jax.Array:
    return jnp.swapaxes(x, -1, -2)


# ---------------------------------------------------------------------------
# Sparse matrix-vector product (spmv / prank / sssp benchmarks)
# ---------------------------------------------------------------------------


def spmv_ell(vals: jax.Array, cols: jax.Array, x: jax.Array) -> jax.Array:
    """ELL-format SpMV: y[r] = sum_k vals[r,k] * x[cols[r,k]].

    Padding entries carry ``vals == 0`` (their column index is arbitrary but
    in-range), so they contribute nothing.
    """
    xg = jnp.take(x, cols, axis=0, mode="clip")
    return jnp.sum(vals * xg, axis=-1)


def csr_to_ell(
    indptr: np.ndarray, indices: np.ndarray, data: np.ndarray, n_rows: int,
    pad_to: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Convert CSR arrays to padded ELL (vals, cols) numpy arrays."""
    counts = np.diff(indptr)
    k = int(counts.max()) if pad_to is None else pad_to
    vals = np.zeros((n_rows, k), dtype=data.dtype)
    cols = np.zeros((n_rows, k), dtype=indices.dtype)
    for r in range(n_rows):
        lo, hi = indptr[r], indptr[r + 1]
        vals[r, : hi - lo] = data[lo:hi]
        cols[r, : hi - lo] = indices[lo:hi]
    return vals, cols


# ---------------------------------------------------------------------------
# Attention (training/prefill flash + decode)
# ---------------------------------------------------------------------------


def _attn_mask(
    q_len: int,
    kv_len: int,
    causal: bool,
    window: Optional[int],
    q_offset: int = 0,
) -> jax.Array:
    """(q_len, kv_len) boolean mask; True = attend."""
    qi = q_offset + jnp.arange(q_len)[:, None]
    kj = jnp.arange(kv_len)[None, :]
    m = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        m &= qi >= kj
    if window is not None:
        m &= qi - kj < window
    return m


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference attention. q (B,H,S,D); k,v (B,KVH,Skv,D); GQA by repeat.

    ``window`` is the sliding-window size (gemma3-style local attention);
    ``q_offset`` positions queries relative to keys (decode/prefill chunking).
    """
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    mask = _attn_mask(sq, k.shape[2], causal, window, q_offset)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    # Fully-masked rows (can happen in padded decode) produce NaN; zero them.
    w = jnp.where(jnp.isnan(w), 0.0, w)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    scale: Optional[float] = None,
) -> jax.Array:
    """Decode attention over a paged KV cache (oracle).

    q:          (B, H, D)           one new query token per sequence
    k/v_pages:  (P, page, KVH, D)   global physical page pool
    page_table: (B, pages_per_seq)  int32 physical page ids (indirect stream)
    lengths:    (B,)                current KV length per sequence
    """
    b, h, d = q.shape
    pages_per_seq = page_table.shape[1]
    page = k_pages.shape[1]
    kvh = k_pages.shape[2]
    # Gather each sequence's logical KV: (B, pages_per_seq, page, KVH, D)
    kg = jnp.take(k_pages, page_table, axis=0)
    vg = jnp.take(v_pages, page_table, axis=0)
    skv = pages_per_seq * page
    kg = kg.reshape(b, skv, kvh, d).transpose(0, 2, 1, 3)
    vg = vg.reshape(b, skv, kvh, d).transpose(0, 2, 1, 3)
    rep = h // kvh
    kg = jnp.repeat(kg, rep, axis=1)
    vg = jnp.repeat(vg, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhd,bhkd->bhk", q, kg).astype(jnp.float32) * scale
    mask = jnp.arange(skv)[None, :] < lengths[:, None]
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    return jnp.einsum("bhk,bhkd->bhd", w, vg.astype(jnp.float32)).astype(q.dtype)


def paged_prefill_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    ctx_rows: jax.Array,
    starts: jax.Array,
    counts: jax.Array,
    scale: Optional[float] = None,
) -> jax.Array:
    """Causal chunk attention over a paged KV pool (oracle, dense einsum).

    q:         (R, C, H, D)    chunk queries; row r's query ``c`` is at
               absolute position ``starts[r] + c``
    k/v_pages: (P, page, KVH, D) physical pool (chunk K/V already written)
    ctx_rows:  (R, ctx_pages)  leading page-table entries per row
    starts/counts: (R,) int32; ``counts[r] == 0`` rows are padding

    Gathers each row's bounded context densely, repeats K/V for GQA, and
    masks with a *finite* constant (``jnp.finfo.min``) so fully-masked rows
    (padding rows, degenerate starts) can never produce NaN softmax outputs
    — their weights are zeroed instead, matching the kernel's skipped
    blocks.  This is the pre-kernel serving prefill path, kept verbatim as
    the ground truth the Pallas kernel is validated against.
    """
    r, c, h, d = q.shape
    page = k_pages.shape[1]
    kvh = k_pages.shape[2]
    ctx_pages = ctx_rows.shape[1]
    skv = ctx_pages * page
    kg = jnp.take(k_pages, ctx_rows.reshape(-1), axis=0).reshape(
        r, skv, kvh, d
    )
    vg = jnp.take(v_pages, ctx_rows.reshape(-1), axis=0).reshape(
        r, skv, kvh, d
    )
    rep = h // kvh
    kg = jnp.repeat(kg, rep, axis=2)                       # (R, S, H, D)
    vg = jnp.repeat(vg, rep, axis=2)
    pos = starts[:, None] + jnp.arange(c, dtype=jnp.int32)  # (R, C)
    kv_pos = jnp.arange(skv, dtype=jnp.int32)
    # Padding rows (counts == 0) are fully masked regardless of ``starts``:
    # their context bound is forced to zero, so they output exact zeros.
    live = jnp.where(counts > 0, starts + counts, 0)
    mask = (kv_pos[None, None, :] <= pos[:, :, None]) & (
        kv_pos[None, None, :] < live[:, None, None]
    )                                                      # (R, C, S)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    s = jnp.einsum("rchd,rshd->rchs", q, kg).astype(jnp.float32) * scale
    s = jnp.where(mask[:, :, None, :], s, jnp.finfo(s.dtype).min)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(mask[:, :, None, :], w, 0.0)
    out = jnp.einsum("rchs,rshd->rchd", w, vg.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_verify_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    ctx_rows: jax.Array,
    lengths: jax.Array,
    counts: jax.Array,
    scale: Optional[float] = None,
) -> jax.Array:
    """Multi-query speculative verify attention (oracle, dense einsum).

    q:         (B, K, H, D)   verify queries — query ``i`` of row ``r`` is
               the token at absolute position ``lengths[r] + i``
    k/v_pages: (P, page, KVH, D) physical pool (the K query tokens' K/V
               already written, append-then-attend as in prefill)
    ctx_rows:  (B, ctx_pages) leading page-table entries per row
    lengths:   (B,) context tokens per row *before* this verify chunk
    counts:    (B,) valid query tokens per row (0 = padding row, zero out)

    A verify chunk is a causal prefill chunk appended at the context tail,
    so the oracle *is* :func:`paged_prefill_attention` with
    ``starts = lengths`` — one definition, shared bit-for-bit with the
    serving prefill path.
    """
    return paged_prefill_attention(
        q, k_pages, v_pages, ctx_rows, lengths, counts, scale=scale
    )


def speculative_accept(
    drafts: jax.Array, greedy: jax.Array, counts: jax.Array
) -> jax.Array:
    """Greedy accept/reject for speculative decoding (on-device, exact).

    drafts: (B, K-1) int32 draft tokens d_1..d_{K-1} (positions after the
            feed token)
    greedy: (B, K)   int32 argmax of the verify logits at every position
            (``greedy[:, i]`` is the model's true next token after
            position ``lengths + i``)
    counts: (B,)     int32 query tokens actually scored per row (0..K;
            capacity clamping / inactive rows give 0)

    Returns ``n_emit`` (B,) int32 — how many of the K scored tokens are
    *emitted* per row: the accepted draft prefix plus the model's one
    bonus token, capped at ``counts``.  Draft ``i`` is accepted iff every
    draft before it matched too (first-mismatch truncation):

        a      = Σ_i  Π_{j<=i} [drafts[j] == greedy[j]]
        n_emit = min(a + 1, counts)

    With K == 1 (no drafts) this is ``min(1, counts)`` — plain decode.
    Greedy acceptance is exact: the emitted tokens ``greedy[:, :n_emit]``
    are bitwise the tokens non-speculative decode would have produced.
    """
    match = (drafts == greedy[:, : drafts.shape[1]]).astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    return jnp.minimum(a + 1, counts).astype(jnp.int32)


def paged_kv_append(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    active: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
):
    """Append one KV token per sequence into the paged pool (oracle).

    The write side of the paged indirect stream: each sequence scatters its
    new K/V row to ``(page_table[b, len_b // page], len_b % page)``.

    k/v_pages:  (P, page, KVH, D) physical pool
    k/v_new:    (B, KVH, D)       one new token per sequence
    page_table: (B, pages_per_seq) int32; lengths: (B,) int32
    active:     (B,) bool — inactive sequences write nothing and keep their
                length (their scatter is routed out of bounds and dropped).
    k/v_scale:  optional (P, page, KVH) fp32 scale pools (the int8 pool
                layout — see :func:`quantize_kv`).  When given, ``k_new`` /
                ``v_new`` are quantized on write: the int8 codes land in the
                pages, the per-(page-token, kv-head) scales in the scale
                pools, through the *same* scatter indices.

    Returns ``(k_pages, v_pages, new_lengths)`` — plus ``(k_scale, v_scale)``
    appended when quantizing.
    """
    p, page, _, _ = k_pages.shape
    quantized = k_scale is not None
    if quantized:
        k_new, k_s = quantize_kv(k_new)
        v_new, v_s = quantize_kv(v_new)
    slot = lengths // page
    off = lengths % page
    pids = jnp.take_along_axis(page_table, slot[:, None], axis=1)[:, 0]
    if active is None:
        active = jnp.ones_like(lengths, dtype=bool)
    # Route inactive writes past the pool; scatter mode='drop' discards them.
    pids = jnp.where(active, pids, p)
    k_pages = k_pages.at[pids, off].set(k_new, mode="drop")
    v_pages = v_pages.at[pids, off].set(v_new, mode="drop")
    new_len = lengths + active.astype(lengths.dtype)
    if quantized:
        k_scale = k_scale.at[pids, off].set(k_s, mode="drop")
        v_scale = v_scale.at[pids, off].set(v_s, mode="drop")
        return k_pages, v_pages, new_len, k_scale, v_scale
    return k_pages, v_pages, new_len


def paged_kv_write_chunk(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    rows: jax.Array,
    starts: jax.Array,
    counts: jax.Array,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
):
    """Scatter one prefill chunk per sequence into the paged pool (oracle).

    The batched write side of chunked prefill: sequence ``r`` writes its
    ``counts[r]`` leading rows of ``k_new[r]``/``v_new[r]`` at absolute
    positions ``starts[r] + c`` through its page-table row ``rows[r]``.

    k/v_pages: (P, page, KVH, D) physical pool
    k/v_new:   (R, C, KVH, D)    chunk of new tokens per sequence
    rows:      (R, n_pages) int32 page-table rows; starts/counts: (R,) int32
    k/v_scale: optional (P, page, KVH) fp32 scale pools.  When given, the
               chunk is quantized on write (:func:`quantize_kv`): int8 codes
               into the pages, per-(page-token, kv-head) scales into the
               scale pools, through the same scatter indices.

    Rows with ``counts[r] == 0`` write nothing (their scatters are routed out
    of bounds and dropped), so the caller can pad the batch freely.

    Returns ``(k_pages, v_pages)`` — plus ``(k_scale, v_scale)`` appended
    when quantizing.
    """
    p, page, kvh, d = k_pages.shape
    r, c = k_new.shape[:2]
    n_pages = rows.shape[1]
    quantized = k_scale is not None
    if quantized:
        k_new, k_s = quantize_kv(k_new)
        v_new, v_s = quantize_kv(v_new)
    pos = starts[:, None] + jnp.arange(c, dtype=jnp.int32)          # (R, C)
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < counts[:, None]
    pids = jnp.take_along_axis(
        rows, jnp.clip(pos // page, 0, n_pages - 1), axis=1
    )                                                                # (R, C)
    flat = jnp.where(valid, pids * page + pos % page, p * page)
    flat = flat.reshape(-1)
    kf = k_pages.reshape(p * page, kvh, d)
    vf = v_pages.reshape(p * page, kvh, d)
    kf = kf.at[flat].set(k_new.reshape(-1, kvh, d), mode="drop")
    vf = vf.at[flat].set(v_new.reshape(-1, kvh, d), mode="drop")
    k_pages = kf.reshape(p, page, kvh, d)
    v_pages = vf.reshape(p, page, kvh, d)
    if quantized:
        ks = k_scale.reshape(p * page, kvh)
        vs = v_scale.reshape(p * page, kvh)
        ks = ks.at[flat].set(k_s.reshape(-1, kvh), mode="drop")
        vs = vs.at[flat].set(v_s.reshape(-1, kvh), mode="drop")
        return (k_pages, v_pages,
                ks.reshape(p, page, kvh), vs.reshape(p, page, kvh))
    return k_pages, v_pages


# ---------------------------------------------------------------------------
# MoE dispatch / combine (packed token routing)
# ---------------------------------------------------------------------------


def moe_dispatch(
    tokens: jax.Array, expert_idx: jax.Array, num_experts: int, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pack tokens into per-expert buffers (oracle for the packed dispatch).

    tokens:     (T, D) flattened token activations
    expert_idx: (T, K) top-k expert assignment per token
    Returns (buffers (E, C, D), src_index (E, C) original (token*K+k) slot or
    -1 for empty, keep_mask (T, K) whether each assignment was kept).
    """
    t, d = tokens.shape
    k = expert_idx.shape[1]
    flat_e = expert_idx.reshape(-1)                      # (T*K,)
    # Position of each assignment within its expert (stable order).
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)  # (TK, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot - onehot   # rank within expert
    pos_in_e = jnp.sum(pos, axis=1)                      # (TK,)
    keep = pos_in_e < capacity
    slot = jnp.where(keep, flat_e * capacity + pos_in_e, num_experts * capacity)
    src = jnp.full((num_experts * capacity + 1,), -1, dtype=jnp.int32)
    src = src.at[slot].set(jnp.arange(t * k, dtype=jnp.int32))[:-1]
    tok_rep = jnp.repeat(tokens, k, axis=0)              # (TK, D)
    buf = jnp.where(
        (src >= 0)[:, None], jnp.take(tok_rep, jnp.maximum(src, 0), axis=0), 0.0
    )
    return (
        buf.reshape(num_experts, capacity, d),
        src.reshape(num_experts, capacity),
        keep.reshape(t, k),
    )


def moe_combine(
    outputs: jax.Array,
    src_index: jax.Array,
    gate_weights: jax.Array,
    num_tokens: int,
) -> jax.Array:
    """Un-pack expert outputs back to token order with gate weighting.

    outputs:      (E, C, D) expert results
    src_index:    (E, C)    original token*K+k slot (or -1)
    gate_weights: (T, K)    router weights
    """
    e, c, d = outputs.shape
    k = gate_weights.shape[1]
    flat_out = outputs.reshape(e * c, d)
    flat_src = src_index.reshape(e * c)
    contrib = jnp.zeros((num_tokens * k, d), dtype=outputs.dtype)
    contrib = contrib.at[jnp.maximum(flat_src, 0)].add(
        jnp.where((flat_src >= 0)[:, None], flat_out, 0.0)
    )
    contrib = contrib.reshape(num_tokens, k, d)
    return jnp.einsum("tkd,tk->td", contrib, gate_weights.astype(outputs.dtype))


# ---------------------------------------------------------------------------
# Int8 packing (gradient compression / quantized KV)
# ---------------------------------------------------------------------------


def int8_quantize(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-slice int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(token, kv-head) int8 quantization of new KV rows.

    ``x`` has shape ``(..., KVH, D)``; each ``(..., kv-head)`` slice is
    quantized symmetrically over its ``D`` components.  Returns the int8
    codes (same shape) and the fp32 scales with the ``D`` axis dropped
    (``(..., KVH)``) — exactly the scale-pool layout the paged kernels
    prefetch (one scale per page token slot per KV head).
    """
    q, scale = int8_quantize(x, axis=-1)
    return q, scale[..., 0]


def dequantize_pages(
    pages: jax.Array, scale: jax.Array, dtype=jnp.float32
) -> jax.Array:
    """Dequantize an int8 page pool with per-(page-token, kv-head) scales.

    ``pages`` is ``(..., page, KVH, D)`` int8; ``scale`` is the matching
    ``(..., page, KVH)`` fp32 pool (no ``D`` axis — one scale per token slot
    per KV head).  The single scale-broadcast rule shared by every ``ref``
    dequant fallback and mirrored element-wise inside the Pallas kernels'
    VMEM dequant, so the oracle and kernel can never disagree on layout.
    """
    return int8_dequantize(pages, scale[..., None], dtype)
