"""Tiled matrix transpose kernel (the ismt strided-stream benchmark).

The paper's ``ismt`` swaps elements above/below the diagonal with strided
accesses.  The TPU-native formulation streams (bt × bt) tiles: the input
tile at (j, i) is a *strided tile stream* relative to the output walk at
(i, j) — each output tile's source is one stride-length away in the transposed
direction, and the tile itself is transposed on the VPU between two dense
DMAs.  BASE-equivalent behaviour (per-element narrow access) is what XLA's
generic gather would do; the packed version moves only full tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transpose_body(x_ref, out_ref):
    out_ref[...] = jnp.swapaxes(x_ref[...], 0, 1)


def transpose_kernel(
    x: jax.Array, block: int = 128, interpret: bool = False
) -> jax.Array:
    """Transpose a 2-D array with (block × block) VMEM tiles."""
    r, c = x.shape
    assert r % block == 0 and c % block == 0, "wrapper must pad to block"
    return pl.pallas_call(
        _transpose_body,
        grid=(r // block, c // block),
        in_specs=[pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((c, r), x.dtype),
        interpret=interpret,
    )(x)
