"""Logical-dim → mesh-axis sharding rules (DP / TP / EP / SP + pod axis).

Every parameter and activation dim carries a *logical name*; this module maps
names to physical mesh axes per run mode.  The same model code therefore runs
on a laptop mesh (1 device), a 256-chip pod (16×16 data×model) and the
2-pod production mesh (2×16×16 pod×data×model) purely by swapping rules.

Design (DESIGN.md §5):
* batch            → ('pod','data')  — DP; gradient all-reduce lowers to the
                      hierarchical intra-pod RS + inter-pod AR + intra-pod AG.
* heads/d_ff/vocab/experts → 'model' — TP / EP.
* weight d_model   → 'data' when cfg.fsdp (ZeRO-3-style param sharding).
* cache seq        → 'model' for decode (flash-decoding SP: each device
                      streams its KV shard; softmax reductions over the
                      sharded seq dim lower to psums automatically).
* long-context (batch < data axis): cache seq over ('data','model').
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import Param, map_params

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical dim name → mesh axis (or axes).

    ``mesh`` is optional: when present, modules may use explicit shard_map
    collectives (e.g. the shard-local embedding gather) instead of relying on
    the SPMD partitioner's gather handling.
    """

    rules: Dict[str, Axis]
    mesh: Optional[Mesh] = None

    def axis(self, name: Optional[str]) -> Axis:
        if name is None:
            return None
        return self.rules.get(name)

    def spec(self, dims: Sequence[Optional[str]]) -> P:
        return P(*[self.axis(d) for d in dims])

    def axis_size(self, name: str) -> int:
        if self.mesh is None:
            return 1
        return mesh_axis_size(self.mesh, self.axis(name))


def make_rules(
    *,
    fsdp: bool = False,
    fsdp_mlp: Optional[bool] = None,  # None: follow fsdp
    shard_kv_heads: bool = False,
    batch_axes: Axis = ("pod", "data"),
    cache_seq_axes: Axis = "model",
    cache_batch_axes: Axis = "data",
    with_pod: bool = True,
    mesh: Optional[Mesh] = None,
) -> ShardingRules:
    if batch_axes is None:
        batch = None
    elif with_pod:
        batch = batch_axes
    else:
        axes = (batch_axes,) if isinstance(batch_axes, str) else batch_axes
        batch = tuple(a for a in axes if a != "pod") or None
    rules: Dict[str, Axis] = {
        "batch": batch,
        "act_batch": batch,
        "seq": None,
        "d_model": None,
        "heads": "model",
        "kv_heads": "model" if shard_kv_heads else None,
        # weight kv dim is separate from the cache kv dim: serving shards
        # wk/wv over 'model' even while the cache shards sequence there
        # (k/v projections re-gather trivially: one token per step).
        "kv_heads_w": "model",
        "head_dim": None,
        "d_ff": "model",
        "vocab": "model",
        "experts": "model",
        "capacity": "data",
        "layers": None,
        # FSDP shards weight d_model over data (and pod when present: a 480B
        # config only fits its optimizer+grads at ≥512-chip scale).
        "fsdp": (("data", "pod") if with_pod else "data") if fsdp else None,
        # MLP weights can stay FSDP-sharded while attention goes model-only
        # (serving capacity/bandwidth split — EXPERIMENTS.md §Perf qwen decode).
        "fsdp_mlp": (("data", "pod") if with_pod else "data")
        if (fsdp if fsdp_mlp is None else fsdp_mlp) else None,
        "cache_batch": cache_batch_axes,
        "cache_seq": cache_seq_axes,
        "ssm_state": None,
        "frontend": None,
    }
    return ShardingRules(rules, mesh=mesh)


def param_specs(defs: Any, rules: ShardingRules) -> Any:
    """Param-def tree → PartitionSpec tree (same structure)."""
    return map_params(lambda p: rules.spec(p.dims), defs)


def param_shardings(defs: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    return map_params(lambda p: NamedSharding(mesh, rules.spec(p.dims)), defs)


def constrain(x: jax.Array, rules: ShardingRules, dims: Sequence[Optional[str]]):
    """with_sharding_constraint by logical dim names (no-op outside jit mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(dims))
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (unit tests on CPU)


# ---------------------------------------------------------------------------
# Divisibility validation — catches bad (arch × mesh) pairings before lower().
# ---------------------------------------------------------------------------


def mesh_axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    size = 1
    for a in axis:
        size *= mesh.shape[a]
    return size


def validate_divisibility(defs: Any, rules: ShardingRules, mesh: Mesh) -> None:
    """Assert every sharded param dim divides its mesh axis product."""
    problems = []

    def check(p: Param):
        for size, dim in zip(p.shape, p.dims):
            ax = rules.axis(dim)
            n = mesh_axis_size(mesh, ax)
            if size % n:
                problems.append(f"dim {dim}={size} not divisible by {ax}({n})")
        return None

    map_params(check, defs)
    if problems:
        raise ValueError("sharding divisibility violations:\n" + "\n".join(problems))
