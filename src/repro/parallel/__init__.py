"""Parallelism: mesh builders, logical-dim sharding rules, validation."""
from .sharding import (
    ShardingRules,
    constrain,
    make_rules,
    param_shardings,
    param_specs,
    validate_divisibility,
)
