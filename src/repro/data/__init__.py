"""Data pipeline: memmap token shards, deterministic per-host batching."""
from .pipeline import TokenDataset, make_frontend_batch, synthetic_corpus, write_corpus
