"""Data pipeline: memmap-backed token shards, packing, deterministic
per-host sharding, and synthetic corpora for the examples/tests.

Layout on disk: a directory of ``shard_*.bin`` (uint32 token streams) plus
``meta.json``.  The :class:`TokenDataset` cuts fixed-length windows
(seq_len + 1) deterministically from (epoch, host, step), so every host
reads a disjoint slice with no coordination — restart-safe: the loader is a
pure function of the step counter recorded in checkpoints.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def write_corpus(
    path: str, tokens: np.ndarray, shard_size: int = 1 << 20
) -> None:
    os.makedirs(path, exist_ok=True)
    tokens = np.asarray(tokens, np.uint32)
    n_shards = max(1, -(-len(tokens) // shard_size))
    for i in range(n_shards):
        tokens[i * shard_size : (i + 1) * shard_size].tofile(
            os.path.join(path, f"shard_{i:05d}.bin")
        )
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"n_tokens": int(len(tokens)), "n_shards": n_shards,
                   "shard_size": shard_size}, f)


def synthetic_corpus(path: str, n_tokens: int, vocab: int, seed: int = 0) -> None:
    """A learnable synthetic corpus: order-2 Markov stream (not uniform noise,
    so training loss can actually decrease in the examples)."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.ones(min(vocab, 64)) * 0.1, size=min(vocab, 64))
    toks = np.zeros(n_tokens, np.uint32)
    s = 0
    for i in range(n_tokens):
        s = rng.choice(min(vocab, 64), p=trans[s])
        toks[i] = s
    write_corpus(path, toks)


@dataclasses.dataclass
class TokenDataset:
    path: str
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        with open(os.path.join(self.path, "meta.json")) as f:
            self.meta = json.load(f)
        self.shards = [
            np.memmap(os.path.join(self.path, f"shard_{i:05d}.bin"),
                      dtype=np.uint32, mode="r")
            for i in range(self.meta["n_shards"])
        ]
        self.n_tokens = self.meta["n_tokens"]
        self.windows = self.n_tokens // (self.seq_len + 1)
        assert self.global_batch % self.n_hosts == 0
        self.host_batch = self.global_batch // self.n_hosts

    def _window(self, idx: int) -> np.ndarray:
        start = idx * (self.seq_len + 1)
        out = np.empty(self.seq_len + 1, np.uint32)
        got = 0
        ssz = self.meta["shard_size"]
        while got < self.seq_len + 1:
            sh, off = divmod(start + got, ssz)
            take = min(self.seq_len + 1 - got, ssz - off)
            out[got : got + take] = self.shards[sh][off : off + take]
            got += take
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for (step, host) — disjoint across hosts."""
        base = (step * self.global_batch + self.host_id * self.host_batch)
        idxs = [(base + i) % self.windows for i in range(self.host_batch)]
        rows = np.stack([self._window(i) for i in idxs])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "targets": rows[:, 1:].astype(np.int32),
            "mask": np.ones((self.host_batch, self.seq_len), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_frontend_batch(
    batch: Dict[str, np.ndarray], cfg, rng: Optional[np.random.Generator] = None
) -> Dict[str, np.ndarray]:
    """Attach stub modality embeddings (audio frames / ViT patches)."""
    rng = rng or np.random.default_rng(0)
    b, s = batch["tokens"].shape
    if cfg.modality == "audio":
        return {
            "frontend": rng.normal(size=(b, s, cfg.frontend_dim)).astype(np.float32),
            "targets": batch["targets"],
            "mask": batch["mask"],
        }
    if cfg.modality == "vlm":
        lf = cfg.frontend_len
        return {
            "tokens": batch["tokens"][:, : s - lf],
            "frontend": rng.normal(size=(b, lf, cfg.frontend_dim)).astype(np.float32),
            "targets": batch["targets"],
            "mask": batch["mask"],
        }
    return batch
