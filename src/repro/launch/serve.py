"""Serving launcher: load/init a model, run batched generation.

Also home of :func:`dense_generate`, the minimal whole-cache prefill+decode
greedy loop (the pre-paged serving baseline).  Production-shaped serving —
paged or recurrent state pools, continuous batching, chaos — lives in
:mod:`repro.serve`; this loop exists for launcher smoke runs and as the
simplest reference generation path over the full ``repro.models.lm`` stack
(norms, MLPs, w8a16 — everything the paged/recurrent serving engines
deliberately strip away).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import lm
from repro.parallel.sharding import ShardingRules, make_rules


def _sample(logits, vocab: int, greedy: bool, rng, step: int):
    logits = logits[..., :vocab]  # drop TP padding classes
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.fold_in(rng, step)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def dense_generate(
    cfg, params, rules: ShardingRules, prompts: jax.Array, n_new: int,
    max_len: int = 512, greedy: bool = True,
    rng: Optional[jax.Array] = None,
) -> np.ndarray:
    """prompts (B, S0) int32 → (B, n_new) generated ids.

    Whole-cache prefill then one decode step per token over the full LM
    stack — the dense serving baseline the old ``ServeEngine`` wrapped.
    """
    b, s0 = prompts.shape
    cache = lm.init_cache(cfg, b, max_len)
    prefill = jax.jit(lambda p, bt, c: lm.prefill(p, bt, c, cfg, rules))
    decode = jax.jit(
        lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, rules)
    )
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    out = []
    tok = _sample(logits[:, 0], cfg.vocab, greedy, rng, 0)
    for i in range(n_new):
        out.append(tok)
        logits, cache = decode(params, tok[:, None], cache, s0 + i)
        tok = _sample(logits, cfg.vocab, greedy, rng, i + 1)
    return np.stack([np.asarray(t) for t in out], axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    rules = make_rules(with_pod=False, batch_axes=("data",))
    params = lm.init_model(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.monotonic()
    out = dense_generate(cfg, params, rules, prompts, args.new_tokens,
                         max_len=args.max_len)
    dt = time.monotonic() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
