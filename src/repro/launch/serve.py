"""Serving launcher: load/init a model, run batched generation.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import lm
from repro.parallel.sharding import make_rules
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    rules = make_rules(with_pod=False, batch_axes=("data",))
    params = lm.init_model(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, rules, max_len=args.max_len,
                         batch=args.batch)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.monotonic()
    out = engine.generate(prompts, args.new_tokens)
    dt = time.monotonic() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
