"""Launchers: production mesh, multi-pod dry-run, train and serve drivers.

NOTE: import ``repro.launch.dryrun`` only as a __main__ entry point — it sets
the 512-fake-device XLA flag at import time.
"""
