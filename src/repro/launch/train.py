"""Training launcher: end-to-end driver wiring configs → data → step → FT.

On this CPU container it trains reduced configs for real (see
examples/train_moe.py); on a TPU cluster the same entry point runs the full
configs — the mesh builder, sharding rules and step factory are identical to
what the dry-run lowers.

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
        --steps 100 --global-batch 8 --seq-len 128
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import TokenDataset, make_frontend_batch, synthetic_corpus
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.optim import OptimizerConfig, make_optimizer
from repro.parallel.sharding import make_rules
from repro.runtime import FaultToleranceConfig, TrainController
from repro.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-dir", default="/tmp/repro_corpus")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rules = make_rules(with_pod=False, batch_axes=("data",))
    mesh = make_host_mesh(data=1, model=1)

    if not os.path.exists(os.path.join(args.data_dir, "meta.json")):
        synthetic_corpus(args.data_dir, n_tokens=200_000, vocab=cfg.vocab,
                         seed=args.seed)
    ds = TokenDataset(args.data_dir, args.seq_len, args.global_batch)

    opt = make_optimizer(OptimizerConfig(
        name=cfg.optimizer, lr=args.lr, warmup_steps=20, total_steps=args.steps
    ))
    params = lm.init_model(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params:,} ({cfg.notes or 'no notes'})")

    raw_step = make_train_step(cfg, opt, rules, grad_accum=args.grad_accum)
    jitted = jax.jit(raw_step, donate_argnums=(0, 1))

    def step_fn(state, batch, step):
        p, o, metrics = jitted(state["params"], state["opt"], batch, step)
        return {"params": p, "opt": o}, metrics

    frng = np.random.default_rng(args.seed)

    def make_batch(step):
        b = ds.batch(step)
        b = make_frontend_batch(b, cfg, frng)
        return {k: jnp.asarray(v) for k, v in b.items()}

    ctl = TrainController(
        step_fn, make_batch,
        FaultToleranceConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
    )
    state = ctl.run({"params": params, "opt": opt_state}, args.steps)
    losses = [h["loss"] for h in ctl.history]
    if losses:
        print(f"first-5 loss {np.mean(losses[:5]):.4f} → last-5 {np.mean(losses[-5:]):.4f}")


if __name__ == "__main__":
    main()
