"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets the fake-device
XLA flag before any jax import.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data*model} devices, have {n}"
    devs = jax.devices()[: data * model]
    import numpy as np

    return Mesh(
        np.array(devs).reshape(data, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def has_pod_axis(mesh: Mesh) -> bool:
    return "pod" in mesh.axis_names
