"""Loop-aware HLO analysis: collective bytes and dot FLOPs from compiled text.

``compiled.cost_analysis()`` visits ``while`` bodies once, so anything under
``lax.scan`` (layer stacks, KV chunks, SSM chunks, loss chunks) is
undercounted.  This parser rebuilds loop-aware totals:

1. split the HLO module into computations;
2. find every ``while`` op, resolve its body/condition computations, and
   read the trip count from the condition's loop-bound constant;
3. propagate multipliers through the call graph (nested scans multiply);
4. sum collective payloads and dot FLOPs × their computation's multiplier.

Wire bytes use the standard ring formulas with the participant group size g
parsed from ``replica_groups``:

    all-reduce       2·(g-1)/g · bytes      reduce-scatter  (g-1)/g · bytes_in
    all-gather       (g-1)/g · bytes_out    all-to-all      (g-1)/g · bytes
    collective-permute  bytes
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_CALLED = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branches=\{)%?([\w\.\-_]+)"
)
_BRANCHES = re.compile(r"branches=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    """'bf16[16,512,128]' → bytes.  Tuples handled by summing members."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_payload: int
    group_size: int
    computation: str
    multiplier: float = 1.0

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        b = self.bytes_payload * self.multiplier
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g * b
        if self.kind == "collective-permute":
            return b
        return (g - 1) / g * b


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation definitions start at column 0 and end with '{'; the name
    is the first token (minus ENTRY/%).  Tuple-typed parameter lists contain
    nested parens, so we deliberately avoid parsing the signature."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            name = line.split()[0]
            if name == "ENTRY":
                name = line.split()[1]
            name = name.lstrip("%").split("(")[0]
            if name in ("HloModule",):
                continue
            cur = name
            comps[cur] = []
            continue
        stripped = line.strip()
        if cur is not None:
            if stripped == "}" or stripped.startswith("} //"):
                cur = None
            else:
                comps[cur].append(stripped)
    return comps


def _called_computations(line: str) -> List[str]:
    names = _CALLED.findall(line)
    mb = _BRANCHES.search(line)
    if mb:
        names += [n.strip().lstrip("%") for n in mb.group(1).split(",")]
    return names


_KNOWN_TRIPS = re.compile(r'known_trip_count"?\s*[:=]\s*\{"?n"?\s*[:=]\s*"?(\d+)')


def _trip_count(while_line: str, cond_lines: List[str]) -> int:
    """Trip count: XLA's known_trip_count annotation, else the loop-bound
    constant in the condition computation."""
    m = _KNOWN_TRIPS.search(while_line)
    if m:
        return int(m.group(1))
    best = 1
    for ln in cond_lines:
        if "constant(" in ln and ("s32" in ln or "u32" in ln):
            for mm in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, int(mm.group(1)))
    return best


def computation_multipliers(hlo: str) -> Tuple[Dict[str, float], Dict[str, List[str]]]:
    comps = split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-_]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        entry = next(iter(comps))

    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        for line in comps[name]:
            called = _called_computations(line)
            if not called:
                continue
            if " while(" in line:
                body = re.search(r"body=%?([\w\.\-_]+)", line)
                cond = re.search(r"condition=%?([\w\.\-_]+)", line)
                trips = _trip_count(
                    line, comps.get(cond.group(1), []) if cond else []
                )
                if body:
                    visit(body.group(1), m * trips, depth + 1)
                if cond:
                    visit(cond.group(1), m * (trips + 1), depth + 1)
            else:
                for c in set(called):
                    visit(c, m, depth + 1)

    visit(entry, 1.0)
    return dict(mult), comps


_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collect_collectives(hlo: str) -> List[CollectiveOp]:
    mult, comps = computation_multipliers(hlo)
    out: List[CollectiveOp] = []
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if not cm or "-done(" in ln:
                continue
            type_str, kind = cm.groups()
            payload = _shape_bytes(type_str)
            g = 1
            gm = _GROUPS_RE.search(ln)
            if gm:
                g = len(gm.group(1).split(","))
            else:
                gi = _GROUPS_IOTA.search(ln)
                if gi:
                    g = int(gi.group(2))
            out.append(CollectiveOp(kind, payload, g, cname, m))
    return out


_DOT_RE = re.compile(r"=\s*(\w+\[[\d,]*\](?:\{[\d,]*\})?)\s+dot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_RE = re.compile(r"dot\((%?[\w\.\-_]+)")
_DEF_RE = re.compile(r"^(%?[\w\.\-_]+)\s*=\s*(\w+\[[\d,]*\])")


def _instruction_shapes(comps: Dict[str, List[str]]) -> Dict[str, str]:
    shapes: Dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                shapes[m.group(1).lstrip("%")] = m.group(2)
    return shapes


def loop_aware_flops(hlo: str) -> float:
    """Σ over dot ops: 2 · prod(out shape) · prod(contracted dims) · mult."""
    mult, comps = computation_multipliers(hlo)
    shapes = _instruction_shapes(comps)
    total = 0.0
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ln in lines:
            dm = _DOT_RE.search(ln)
            if not dm:
                continue
            sm = _SHAPE_RE.search(dm.group(1))
            if not sm:
                continue
            out_elems = 1
            for d in sm.group(2).split(","):
                if d:
                    out_elems *= int(d)
            # contracted size from the lhs operand's recorded shape
            k = 1
            cmatch = _CONTRACT_RE.search(ln)
            lhs = _LHS_RE.search(ln)
            if cmatch and lhs:
                lhs_type = shapes.get(lhs.group(1).lstrip("%"), "")
                sl = _SHAPE_RE.search(lhs_type)
                if sl:
                    dims = [int(d) for d in sl.group(2).split(",") if d]
                    for ci in cmatch.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
            total += 2.0 * out_elems * k * m
    return total


def summarize_collectives(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    agg: Dict[str, Dict[str, float]] = defaultdict(lambda: {"count": 0, "payload": 0.0, "wire": 0.0})
    for op in ops:
        a = agg[op.kind]
        a["count"] += op.multiplier
        a["payload"] += op.bytes_payload * op.multiplier
        a["wire"] += op.wire_bytes
    return dict(agg)
