import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU-backend artifacts that inflate the memory picture vs TPU:
    # WLICM hoists the bf16→f32 convert of the whole remat residual stack
    # out of the backward loop (+7.7 GB/dev on arctic; TPU runs bf16
    # natively so the convert does not exist there).
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,convert-mover "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:

* ``.lower().compile()`` must succeed on the 16×16 single-pod mesh and the
  2×16×16 multi-pod mesh for every live cell (32 of the 40 nominal; skips
  are principled, DESIGN.md §4);
* ``memory_analysis()`` per-device bytes prove the cell fits 16 GB HBM;
* ``cost_analysis()`` + loop-aware HLO parsing feed the §Roofline terms.

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quick]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import ALL_ARCH_NAMES, applicable_shapes, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis
from repro.launch.specs import build_cell

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per chip, one direction)


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool = False,
    save: bool = True,
    analyze_hlo: bool = True,
) -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    cell = build_cell(arch, shape, mesh, tp=16)

    with mesh:
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                 + ma.output_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3
            ),
        },
        "cost_analysis": {
            "flops_per_device_loopbody_once": float(ca.get("flops", -1)),
            "bytes_accessed_loopbody_once": float(ca.get("bytes accessed", -1)),
        },
    }

    if analyze_hlo:
        txt = compiled.as_text()
        rec["hlo_chars"] = len(txt)
        colls = hlo_analysis.collect_collectives(txt)
        rec["collectives"] = hlo_analysis.summarize_collectives(colls)
        rec["collective_wire_bytes_per_device"] = sum(c.wire_bytes for c in colls)
        rec["loop_aware_dot_flops_per_device"] = hlo_analysis.loop_aware_flops(txt)
        del txt

    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        path = os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        rec["artifact"] = path
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-hlo", action="store_true", help="skip HLO text analysis")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ALL_ARCH_NAMES:
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            tag = f"{arch:16s} {shape:12s} {'2x16x16' if multi_pod else '16x16'}"
            try:
                rec = run_cell(arch, shape, multi_pod, analyze_hlo=not args.no_hlo)
                mem = rec["memory"]["peak_per_device_gb"]
                wire = rec.get("collective_wire_bytes_per_device", 0) / 2**20
                print(f"OK   {tag} mem/dev={mem:7.3f}GB "
                      f"coll={wire:9.1f}MiB compile={rec['compile_s']:.1f}s",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
