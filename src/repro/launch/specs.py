"""Dry-run input specs: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation.  Each (arch × shape) cell maps
to a step function + its abstract inputs + sharding trees; the dry-run lowers
``jax.jit(step, in_shardings=...)`` against these.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.models import lm
from repro.models.common import Param, map_params
from repro.optim import OptimizerConfig, make_optimizer
from repro.parallel.sharding import ShardingRules, make_rules, param_specs
from repro.train import make_train_step

SDS = jax.ShapeDtypeStruct


def rules_for(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> ShardingRules:
    """Shape-kind-aware rules (DESIGN.md §5).

    Decode/prefill shard the KV cache over sequence ('model' axis; flash-
    decoding SP) so kv_heads stay replicated there; training shards kv heads
    when the config allows.  long_500k (batch=1 < data axis) shards cache
    sequence over (data, model) and leaves batch unsharded.
    """
    with_pod = "pod" in mesh.axis_names
    batch_axes: Any = ("pod", "data") if with_pod else ("data",)
    cache_seq: Any = "model"
    cache_batch: Any = ("pod", "data") if with_pod else "data"
    if shape.kind == "train":
        return make_rules(
            fsdp=cfg.fsdp,
            shard_kv_heads=cfg.shard_kv_heads,
            batch_axes=batch_axes,
            with_pod=with_pod,
            mesh=mesh,
        )
    # serving kinds
    if shape.global_batch % (np.prod([mesh.shape[a] for a in batch_axes])) != 0:
        # batch too small for the data axis (long_500k): shard seq over all.
        batch_axes = None
        cache_batch = None
        cache_seq = ("data", "model") if not with_pod else ("pod", "data", "model")
    # Serving weight-sharding split (§Perf): FSDP-style data-axis weight
    # sharding forces per-token all-gathers of every layer's weights — the
    # dominant decode collective (7.8 GB/token on qwen1.5-32b).  Attention
    # weights go model-only (hot path, small); MLP weights keep the data-axis
    # shard only where model-only weights would not fit HBM next to the cache.
    serving_fsdp_mlp = (cfg.fsdp and not cfg.serve_mlp_int8
                        and cfg.param_count() * 2 / 16 > 3e9)
    return make_rules(
        fsdp=False,
        fsdp_mlp=serving_fsdp_mlp,
        shard_kv_heads=False,
        batch_axes=batch_axes,
        cache_seq_axes=cache_seq,
        cache_batch_axes=cache_batch,
        with_pod=with_pod,
        mesh=mesh,
    )


def batch_specs(
    cfg: ArchConfig, shape: ShapeConfig, rules: ShardingRules, mesh: Mesh
) -> Tuple[Dict[str, SDS], Dict[str, NamedSharding]]:
    """Abstract train/prefill batch + shardings."""
    b, s = shape.global_batch, shape.seq_len
    bspec = rules.spec(("batch", "seq"))
    sds: Dict[str, SDS] = {}
    shd: Dict[str, NamedSharding] = {}

    def add(name, shape_, dtype, spec):
        sds[name] = SDS(shape_, dtype)
        shd[name] = NamedSharding(mesh, spec)

    if cfg.modality == "audio":
        add("frontend", (b, s, cfg.frontend_dim), jnp.float32,
            rules.spec(("batch", "seq", None)))
    else:
        s_text = s - (cfg.frontend_len if cfg.modality == "vlm" else 0)
        add("tokens", (b, s_text), jnp.int32, bspec)
        if cfg.modality == "vlm":
            add("frontend", (b, cfg.frontend_len, cfg.frontend_dim), jnp.float32,
                rules.spec(("batch", None, None)))
    if shape.kind == "train":
        add("targets", (b, s), jnp.int32, bspec)
        add("mask", (b, s), jnp.float32, bspec)
    return sds, shd


def param_structs(
    cfg: ArchConfig, rules: ShardingRules, mesh: Mesh, tp: int,
    serving: bool = False,
):
    """(SDS tree, NamedSharding tree) for the model parameters.

    Serving uses bf16 weights (inference checkpoints are cast once at load);
    training keeps ``cfg.param_dtype``.
    """
    sds = jax.eval_shape(lambda: lm.init_model(cfg, jax.random.PRNGKey(0), tp=tp))
    if serving:
        sds = jax.tree_util.tree_map(
            lambda s: SDS(s.shape, cfg.compute_dtype)
            if jnp.issubdtype(s.dtype, jnp.floating) else s,
            sds,
        )
    specs = param_specs(lm.model_defs(cfg, tp), rules)
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return sds, shardings


def cache_structs(
    cfg: ArchConfig, shape: ShapeConfig, rules: ShardingRules, mesh: Mesh, tp: int
):
    sds = jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len, tp=tp)
    )
    dims = lm.cache_dims_tree(cfg)
    shardings = jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, rules.spec(d)),
        dims,
        is_leaf=lambda d: isinstance(d, tuple),
    )
    return sds, shardings


@dataclasses.dataclass
class Cell:
    """One dry-run cell: step fn + abstract args + shardings."""

    arch: str
    shape: str
    step_fn: Callable
    args_sds: Tuple
    in_shardings: Tuple
    donate: Tuple[int, ...] = ()


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def build_cell(arch: str, shape_name: str, mesh: Mesh, tp: int = 16) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules_for(cfg, shape, mesh)
    p_sds, p_shd = param_structs(cfg, rules, mesh, tp)

    if shape.kind == "train":
        opt = make_optimizer(OptimizerConfig(name=cfg.optimizer))
        o_sds = jax.eval_shape(opt.init, p_sds)
        # optimizer state mirrors parameter sharding leaf-wise (ZeRO via fsdp)
        o_shd = _opt_shardings(o_sds, p_shd, mesh)
        b_sds, b_shd = batch_specs(cfg, shape, rules, mesh)
        # Microbatch so each device holds ≤2 sequences of activations/residual
        # stacks at a time (production practice; keeps every arch <16 GB HBM).
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
        b_local = max(1, shape.global_batch // dp)
        grad_accum = max(1, b_local // 2)
        if os.environ.get("REPRO_GRAD_ACCUM"):
            grad_accum = int(os.environ["REPRO_GRAD_ACCUM"])
        step = make_train_step(cfg, opt, rules, grad_accum=grad_accum)
        return Cell(
            arch, shape_name, step,
            (p_sds, o_sds, b_sds, SDS((), jnp.int32)),
            (p_shd, o_shd, b_shd, replicated(mesh)),
            donate=(0, 1),  # params/opt_state update in place (as in training)
        )

    # Serving: bf16 weights, cache donated (in-place update, no double buffer).
    p_sds, p_shd = param_structs(cfg, rules, mesh, tp, serving=True)
    if cfg.serve_mlp_int8:
        p_sds, p_shd = lm.quantize_mlp_structs(p_sds, p_shd, cfg)

    if shape.kind == "prefill":
        b_sds, b_shd = batch_specs(cfg, shape, rules, mesh)
        c_sds, c_shd = cache_structs(cfg, shape, rules, mesh, tp)

        def prefill_step(params, batch, cache):
            if cfg.prefill_chunk:
                return lm.prefill_chunked(
                    params, batch, cache, cfg, rules, cfg.prefill_chunk
                )
            return lm.prefill(params, batch, cache, cfg, rules)

        return Cell(
            arch, shape_name, prefill_step,
            (p_sds, b_sds, c_sds), (p_shd, b_shd, c_shd), donate=(2,),
        )

    # decode
    rules_d = rules
    c_sds, c_shd = cache_structs(cfg, shape, rules_d, mesh, tp)
    b = shape.global_batch
    tok_sds = SDS((b, 1), jnp.int32)
    tok_shd = NamedSharding(mesh, rules_d.spec(("batch", "seq")))

    def decode(params, tokens, cache, pos):
        return lm.decode_step(params, tokens, cache, pos, cfg, rules_d)

    return Cell(
        arch, shape_name, decode,
        (p_sds, tok_sds, c_sds, SDS((), jnp.int32)),
        (p_shd, tok_shd, c_shd, replicated(mesh)), donate=(2,),
    )


def _opt_shardings(o_sds, p_shd, mesh: Mesh):
    """Leaf-wise: each optimizer slot reuses its parameter's sharding if the
    shape matches; factored/scalar slots fall back to a compatible prefix."""
    flat_p, _ = jax.tree_util.tree_flatten(p_shd)

    def assign(path, leaf):
        # path like ('m'|'v'|..., <param path...>) — match on trailing shape.
        for cand in flat_p:
            pass
        return None

    # Simpler: walk the two trees in parallel where structure matches.
    def match(o_leaf, p_sharding):
        return p_sharding

    # The optimizer state for AdamW is {m: tree, v: tree} with the same
    # structure; Adafactor nests {vr, vc, m} per leaf.  Handle both.
    def build(o_sub, p_sub):
        if isinstance(o_sub, dict) and set(o_sub) <= {"m", "v", "vr", "vc"}:
            out = {}
            for k, v in o_sub.items():
                if hasattr(v, "shape"):
                    out[k] = _compatible_sharding(v, p_sub, mesh)
                else:
                    out[k] = build(v, p_sub)
            return out
        if isinstance(o_sub, dict):
            return {k: build(v, p_sub[k] if isinstance(p_sub, dict) else p_sub)
                    for k, v in o_sub.items()}
        return p_sub

    return build(o_sds, p_shd)


def _compatible_sharding(sds, p_sharding, mesh: Mesh):
    """Sharding for an optimizer slot of shape sds given its param sharding."""
    if not isinstance(p_sharding, NamedSharding):
        return replicated(mesh)
    spec = list(p_sharding.spec)
    nd = len(sds.shape)
    if len(spec) == nd:
        return p_sharding
    # factored slots drop a trailing/penultimate dim: keep the prefix axes
    # that still divide.
    spec = spec[:nd]
    out = []
    for size, ax in zip(sds.shape, spec + [None] * (nd - len(spec))):
        n = 1
        if ax is not None:
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            for a in axes:
                n *= mesh.shape[a]
        out.append(ax if size % max(n, 1) == 0 else None)
    return NamedSharding(mesh, P(*out))
