"""Pure-JAX packing engine: functional semantics of packed irregular streams.

These are the *reference semantics* of AXI-Pack bursts — what the data looks
like after the beat packer has run.  The Pallas kernels in
:mod:`repro.kernels` implement the same functions with explicit HBM→VMEM
streaming; everything here is differentiable, jit-able jnp and serves as the
oracle (``ref``) implementation plus the instrumentation point for traffic
accounting (bytes moved under BASE vs PACK semantics).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "pack_strided",
    "unpack_strided",
    "pack_indirect",
    "unpack_indirect",
    "Traffic",
    "strided_traffic",
    "indirect_traffic",
    "packed_token_bytes",
    "paged_decode_traffic",
    "prefill_page_counts",
    "paged_prefill_traffic",
    "spec_verify_traffic",
    "prefix_share_traffic",
    "recurrent_decode_traffic",
    "recurrent_prefill_traffic",
]


def pack_strided(src: jax.Array, base: int, stride: int, count: int) -> jax.Array:
    """Gather ``count`` rows of ``src`` at ``base + k*stride`` into a dense block.

    ``src`` has shape (n_rows, *row); the result has shape (count, *row).
    With stride == 1 this is a contiguous slice (the base converter path).
    """
    if stride == 1:
        return jax.lax.dynamic_slice_in_dim(src, base, count, axis=0)
    idx = base + stride * jnp.arange(count)
    return jnp.take(src, idx, axis=0)


def unpack_strided(
    dst: jax.Array, packed: jax.Array, base: int, stride: int
) -> jax.Array:
    """Scatter the rows of ``packed`` back to ``dst`` at ``base + k*stride``."""
    count = packed.shape[0]
    if stride == 1:
        return jax.lax.dynamic_update_slice_in_dim(dst, packed, base, axis=0)
    idx = base + stride * jnp.arange(count)
    return dst.at[idx].set(packed)


def pack_indirect(src: jax.Array, indices: jax.Array, base: int = 0) -> jax.Array:
    """Gather rows ``src[base + indices[k]]`` into a dense block.

    The index array is a *memory-resident* JAX array (the in-memory indexed
    semantics of ``vlimxei``): callers never materialize per-element addresses.
    """
    return jnp.take(src, base + indices, axis=0)


def unpack_indirect(
    dst: jax.Array,
    packed: jax.Array,
    indices: jax.Array,
    base: int = 0,
    mode: str = "set",
) -> jax.Array:
    """Scatter rows of ``packed`` to ``dst[base + indices[k]]``.

    ``mode='set'`` mirrors the hardware write converter (last-writer-wins for
    duplicate indices, order unspecified); ``mode='add'`` accumulates, which
    the framework uses for MoE combine and embedding gradients.
    """
    at = dst.at[base + indices]
    return at.add(packed) if mode == "add" else at.set(packed)


# ---------------------------------------------------------------------------
# Traffic accounting: exact bytes moved under each system's semantics.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Traffic:
    """HBM/bus traffic for one logical transfer, per system.

    ``base_bytes`` counts one full bus/transaction granule per element (the
    narrow-beat penalty); ``pack_bytes`` counts densely packed lines;
    ``index_bus_bytes`` is index traffic crossing the core-side bus (zero for
    PACK, whose indirection is endpoint-side).  ``shared_pages`` counts
    physical pages this transfer *reused* instead of re-writing (prefix
    sharing): reuse moves no payload, so those records show
    ``pack_bytes == 0`` while ``useful_bytes`` stays the full value — the
    dedup-before-packing multiplier of the irredundant-layout argument.
    """

    useful_bytes: int
    base_bytes: int
    pack_bytes: int
    index_bus_bytes_base: int
    index_bus_bytes_pack: int = 0
    shared_pages: int = 0

    @property
    def base_efficiency(self) -> float:
        tot = self.base_bytes + self.index_bus_bytes_base
        return self.useful_bytes / tot if tot else 1.0

    @property
    def pack_efficiency(self) -> float:
        tot = self.pack_bytes + self.index_bus_bytes_pack
        return self.useful_bytes / tot if tot else 1.0


def strided_traffic(
    count: int, elem_bytes: int, stride: int, granule_bytes: int = 32
) -> Traffic:
    """Traffic for a strided stream on a ``granule_bytes``-wide bus."""
    useful = count * elem_bytes
    if stride == 1:
        moved = int(np.ceil(useful / granule_bytes)) * granule_bytes
        return Traffic(useful, moved, moved, 0)
    base = count * granule_bytes                      # one narrow beat/elem
    pack = int(np.ceil(useful / granule_bytes)) * granule_bytes
    return Traffic(useful, base, pack, 0)


def indirect_traffic(
    count: int, elem_bytes: int, index_bytes: int, granule_bytes: int = 32
) -> Traffic:
    """Traffic for an indirect stream; indices are packed lines either way."""
    useful = count * elem_bytes
    idx = int(np.ceil(count * index_bytes / granule_bytes)) * granule_bytes
    base = count * granule_bytes
    pack = int(np.ceil(useful / granule_bytes)) * granule_bytes
    # PACK fetches indices endpoint-side: they cost memory bandwidth but not
    # core-side bus bytes; we still report them for the HBM energy proxy.
    return Traffic(useful, base, pack, idx, 0)


def packed_token_bytes(
    token_bytes: int, elem_bits: int = 32, scale_bytes_per_token: int = 0
) -> int:
    """Per-token bytes PACK actually moves for a KV stream.

    ``token_bytes`` is the *FP32-equivalent* (full-width) per-token
    footprint; ``elem_bits`` the real element width on the stream.  Narrow
    elements pack densely, so the payload scales by ``elem_bits / 32`` — the
    paper's packing-factor argument (``bus / elem`` elements per beat,
    §II-C/§III-E): 8-bit elements quadruple the FP32 packing factor.
    ``scale_bytes_per_token`` adds the sideband metadata a quantized pool
    fetches next to the codes (the per-(token, kv-head) fp32 scales), which
    is real bandwidth and is charged to PACK like the index fetch is.
    """
    return token_bytes * elem_bits // 32 + scale_bytes_per_token


def paged_decode_traffic(
    lengths,
    page_size: int,
    pages_per_seq: int,
    token_bytes: int,
    index_bytes: int = 4,
    granule_bytes: int = 32,
    elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Traffic:
    """Traffic of one batched paged-KV decode step, BASE vs PACK.

    * **BASE** is the serving system without indirection or packing: a
      contiguous *full-width* KV cache padded to the maximum sequence
      length, so every decode step streams ``batch × pages_per_seq ×
      page_size`` token rows at ``token_bytes`` each regardless of sequence
      length or element width — the narrow-beat penalty: a narrower element
      still occupies a full-width slot.  No index traffic.
    * **PACK** is the paged path: only the mapped pages of each sequence move
      (whole pages — the packing granule of this stream) at the *packed*
      width (:func:`packed_token_bytes` — ``elem_bits`` narrow elements
      packed densely, plus the quantization-scale sideband), and the
      page-table entries are the indirect-stream index fetch.  The indices
      are resolved near memory, so they are charged to
      ``index_bus_bytes_pack`` (the HBM side), never to the core-side bus —
      but they do lower ``pack_efficiency``, matching the r/(r+1) ceiling
      argument of §III-E.
    * ``useful_bytes`` is the exact live KV at the packed width:
      ``sum(lengths) × packed_token_bytes``.

    ``token_bytes`` is the FP32-equivalent per-token KV footprint across
    everything a decode step reads (K and V, all layers, all KV heads);
    ``elem_bits`` is the pool's element width (8 for int8 pools, which
    quarters PACK bytes and the BASE efficiency alike).
    """
    lens = np.asarray(lengths, dtype=np.int64)
    batch = int(lens.shape[0])
    packed = packed_token_bytes(token_bytes, elem_bits, scale_bytes_per_token)
    pages_touched = int(np.sum(-(-lens // page_size)))
    useful = int(np.sum(lens)) * packed
    base = batch * pages_per_seq * page_size * token_bytes
    pack = pages_touched * page_size * packed
    pack = int(np.ceil(pack / granule_bytes)) * granule_bytes if pack else 0
    idx = pages_touched * index_bytes
    idx = int(np.ceil(idx / granule_bytes)) * granule_bytes if idx else 0
    return Traffic(useful, base, pack, 0, idx)


def prefill_page_counts(
    starts, counts, page_size: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row (context, chunk) page counts of one batched prefill step.

    ``context[r]`` is the leading ``ceil((starts[r]+counts[r])/page)`` table
    entries the chunk's attention walks; ``chunk[r]`` the pages positions
    ``starts[r] .. starts[r]+counts[r]-1`` land in (the indirect write).
    Padding rows (``counts[r] == 0``) touch nothing and count zero pages.

    This is the single source of page math shared by the
    :func:`paged_prefill_traffic` byte accounting and the
    :func:`repro.core.streams.prefill_table_streams` descriptors — the same
    pages the ``paged_prefill_attention`` kernel's index map resolves.
    """
    st = np.asarray(starts, dtype=np.int64)
    ct = np.asarray(counts, dtype=np.int64)
    live = st + ct
    ctx = np.where(ct > 0, -(-live // page_size), 0)
    chunk = np.where(ct > 0, (live - 1) // page_size - st // page_size + 1, 0)
    return ctx, chunk


def paged_prefill_traffic(
    starts,
    counts,
    page_size: int,
    pages_per_seq: int,
    token_bytes: int,
    index_bytes: int = 4,
    granule_bytes: int = 32,
    elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Traffic:
    """Traffic of one batched chunked-prefill step, BASE vs PACK.

    Each sequence writes ``counts[r]`` KV rows at positions ``starts[r]..``
    and its attention re-reads the context built so far.

    * **BASE** streams the full padded row per sequence for the context read
      (``pages_per_seq × page_size`` tokens at the full ``token_bytes``
      width — narrow elements still occupy full-width slots) plus one
      transaction granule per written row — the packing-oblivious scatter.
    * **PACK** reads only the pages covering ``starts + counts`` tokens,
      writes only the pages the chunk touches (whole pages, the stream's
      packing granule), both at the *packed* width
      (:func:`packed_token_bytes`: ``elem_bits`` narrow elements packed
      densely plus the quantization-scale sideband), and fetches the
      corresponding page-table entries near memory
      (``index_bus_bytes_pack``).
    * ``useful_bytes`` is the live context read plus the rows written, at
      the packed width.
    """
    st = np.asarray(starts, dtype=np.int64)
    ct = np.asarray(counts, dtype=np.int64)
    live = np.where(ct > 0, st + ct, 0)
    packed = packed_token_bytes(token_bytes, elem_bits, scale_bytes_per_token)
    ctx, chunk = prefill_page_counts(starts, counts, page_size)
    ctx_pages = int(np.sum(ctx))
    chunk_pages = int(np.sum(chunk))
    useful = int(np.sum(live) + np.sum(ct)) * packed
    batch = int(np.count_nonzero(ct))
    base = (batch * pages_per_seq * page_size * token_bytes
            + int(np.sum(ct)) * granule_bytes)
    pack = (ctx_pages + chunk_pages) * page_size * packed
    pack = int(np.ceil(pack / granule_bytes)) * granule_bytes if pack else 0
    idx = (ctx_pages + chunk_pages) * index_bytes
    idx = int(np.ceil(idx / granule_bytes)) * granule_bytes if idx else 0
    return Traffic(useful, base, pack, 0, idx)


def spec_verify_traffic(
    lengths,
    scored,
    page_size: int,
    pages_per_seq: int,
    token_bytes: int,
    index_bytes: int = 4,
    granule_bytes: int = 32,
    elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Traffic:
    """Traffic of one speculative K-token verify step, BASE vs PACK.

    ``lengths[r]`` is row ``r``'s context before the step and ``scored[r]``
    how many query tokens (feed + drafts, 0 for inactive rows) the verify
    kernel scored in its single walk.  The page math is prefill's with
    ``starts = lengths`` — a verify chunk *is* a causal chunk at the
    context tail — but the **BASE counterfactual is different**, and it is
    the point of the whole speculative path:

    * **BASE** is the non-speculative narrow decoder emitting the same
      tokens one at a time: ``scored[r]`` separate full-width padded walks
      per row (``sum(scored) × pages_per_seq × page_size × token_bytes``)
      plus one transaction granule per written row.  This is what PR-7's
      decode path actually pays per K tokens.
    * **PACK** walks each row's context pages **once** for all K queries
      (the packed indirect burst amortized over the time axis, not just
      the batch axis) and writes only the chunk pages — both at the packed
      width (:func:`packed_token_bytes`), with the page-table entries
      fetched near memory (``index_bus_bytes_pack``).
    * ``useful_bytes`` is one context read plus the rows written, at the
      packed width — same form as prefill.

    The BASE/PACK ratio therefore approaches ``K ×`` the plain-decode
    ratio at full acceptance, degrading gracefully with ``scored``.
    """
    lens = np.asarray(lengths, dtype=np.int64)
    sc = np.asarray(scored, dtype=np.int64)
    live = np.where(sc > 0, lens + sc, 0)
    packed = packed_token_bytes(token_bytes, elem_bits, scale_bytes_per_token)
    ctx, chunk = prefill_page_counts(lens, sc, page_size)
    ctx_pages = int(np.sum(ctx))
    chunk_pages = int(np.sum(chunk))
    useful = int(np.sum(live) + np.sum(sc)) * packed
    base = (int(np.sum(sc)) * pages_per_seq * page_size * token_bytes
            + int(np.sum(sc)) * granule_bytes)
    pack = (ctx_pages + chunk_pages) * page_size * packed
    pack = int(np.ceil(pack / granule_bytes)) * granule_bytes if pack else 0
    idx = (ctx_pages + chunk_pages) * index_bytes
    idx = int(np.ceil(idx / granule_bytes)) * granule_bytes if idx else 0
    return Traffic(useful, base, pack, 0, idx)


def prefix_share_traffic(
    shared_tokens: int,
    n_pages: int,
    page_size: int,
    token_bytes: int,
    index_bytes: int = 4,
    granule_bytes: int = 32,
    elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Traffic:
    """Traffic of mapping an already-resident prompt prefix, BASE vs PACK.

    When admission finds ``n_pages`` page-aligned prefix pages already in
    the pool, the sharing path moves *no KV payload* — it bumps refcounts
    and fetches the ``n_pages`` page-table entries it repoints (charged to
    ``index_bus_bytes_pack``, near-memory like every other table fetch).

    * **BASE** is the dedup-oblivious server: it re-prefills the prefix, so
      it streams ``shared_tokens`` full-width KV writes (one transaction
      granule per row, the packing-oblivious scatter — the write half of
      :func:`paged_prefill_traffic`'s BASE, which is exactly the work
      sharing elides; the context re-reads it also skips are already
      reflected in the *absent* prefill records).
    * **PACK** moves only the table fetch: ``pack_bytes == 0``.
    * ``useful_bytes`` is the prefix KV at the packed width — the bytes the
      pool now serves without them ever crossing the bus again.

    ``pack_efficiency`` of such a record is ``useful / index`` and typically
    far exceeds 1: that is the dedup multiplier on top of packing, and why
    :class:`Traffic` carries ``shared_pages`` so aggregates can report it
    separately rather than silently inflating the packing ratio.
    """
    packed = packed_token_bytes(token_bytes, elem_bits, scale_bytes_per_token)
    useful = shared_tokens * packed
    base = shared_tokens * max(token_bytes, granule_bytes)
    idx = n_pages * index_bytes
    idx = int(np.ceil(idx / granule_bytes)) * granule_bytes if idx else 0
    return Traffic(useful, base, 0, 0, idx, shared_pages=n_pages)


def recurrent_decode_traffic(
    n_active: int,
    batch: int,
    state_bytes: int,
    granule_bytes: int = 32,
) -> Traffic:
    """Traffic of one recurrent (RWKV/Mamba) decode step, BASE vs PACK.

    The strided-burst sibling of :func:`paged_decode_traffic`: a decode
    step is a read-modify-write of each active sequence's fixed-size state
    (``state_bytes`` per sequence — all layers, all state tensors), laid
    out (layer, slot) so one sequence's rows sit at a fixed stride of
    ``batch`` rows (the :func:`repro.core.streams.recurrent_state_streams`
    descriptors).  No index vector exists — the stride *is* the
    descriptor — so unlike the indirect dialect there is no
    ``index_bus_bytes`` term at all.

    * **BASE** is the padded-batch server: it streams the whole (layer,
      batch) state pool through (read + write) regardless of how many
      slots are live — ``2 × batch × state_bytes``.
    * **PACK** issues one strided burst pair per active slot, moving
      exactly its rows (densely packed; granule-rounded):
      ``2 × n_active × state_bytes``.
    * ``useful_bytes`` equals PACK's payload — recurrent state has no dead
      tokens inside a row, so the strided PACK efficiency is ≈ 1 by
      construction while BASE efficiency is the occupancy ``A / batch``.
      That contrast (indirect pays the r/(r+1) index tax, strided does
      not) is exactly the Fig. 3 comparison the serving benchmark reports.
    """
    useful = 2 * int(n_active) * int(state_bytes)
    pack = int(np.ceil(useful / granule_bytes)) * granule_bytes if useful else 0
    base = 2 * int(batch) * int(state_bytes)
    return Traffic(useful, base, pack, 0)


def recurrent_prefill_traffic(
    counts,
    batch: int,
    state_bytes: int,
    granule_bytes: int = 32,
) -> Traffic:
    """Traffic of one batched recurrent prefill chunk, BASE vs PACK.

    A fused prefill chunk loads each pending sequence's state once, scans
    ``counts[r]`` prompt tokens on-chip, and writes the state back once —
    so PACK moves the same ``2 × state_bytes`` per active row a decode
    step does, *independent of the chunk length* (the recurrent analogue
    of prefill's context-read amortization).

    * **BASE** is the packing-oblivious server that re-streams the padded
      (layer, batch) pool per token position of the chunk:
      ``2 × batch × max(counts) × state_bytes``.
    * **PACK** / ``useful_bytes``: ``2 × n_active × state_bytes``,
      granule-rounded (strided bursts are dense — no index term).
    """
    ct = np.asarray(counts, dtype=np.int64)
    n_active = int(np.count_nonzero(ct))
    chunk = int(ct.max()) if ct.size else 0
    useful = 2 * n_active * int(state_bytes)
    pack = int(np.ceil(useful / granule_bytes)) * granule_bytes if useful else 0
    base = 2 * int(batch) * chunk * int(state_bytes)
    return Traffic(useful, base, pack, 0)
