"""Packed irregular stream descriptors — the software form of AXI-Pack requests.

AXI-Pack encodes stream semantics into the AXI4 AR/AW request channels via
user-field bits: ``pack`` (extension active), ``indir`` (strided vs indirect),
and a shared field carrying either the element stride or the index base/size.
This module is the JAX-side equivalent: a :class:`StridedStream` or
:class:`IndirectStream` fully describes an irregular access sequence, and the
rest of the framework (packing engine, Pallas kernels, bus model, bank
simulator) consumes these descriptors instead of raw address lists.

Descriptors are deliberately *dataclasses of ints*, not arrays: like an AXI
request they are cheap metadata travelling ahead of the data.  The index array
of an :class:`IndirectStream` stays *in memory* (a JAX array reference) and is
resolved near-memory (scalar-prefetch in the Pallas kernels, index stage in
the bank simulator) — never round-tripped through the "core side".
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BurstKind",
    "StreamDescriptor",
    "ContiguousStream",
    "StridedStream",
    "IndirectStream",
    "elements_per_beat",
    "beats_for",
    "page_table_streams",
    "prefill_table_streams",
    "verify_table_streams",
    "share_table_streams",
    "recurrent_state_streams",
]


class BurstKind(enum.Enum):
    """The three burst families AXI-Pack distinguishes.

    ``BASE`` corresponds to an ordinary AXI4 contiguous burst (the ``base``
    converter in the paper's controller); ``STRIDED`` and ``INDIRECT`` are the
    two new packed burst types signalled by the ``pack``/``indir`` user bits.
    """

    BASE = "base"
    STRIDED = "strided"
    INDIRECT = "indirect"


def elements_per_beat(bus_bits: int, elem_bits: int) -> int:
    """How many elements a single packed bus beat carries (n = D/W in §II-C)."""
    if elem_bits > bus_bits:
        raise ValueError(f"element ({elem_bits}b) wider than bus ({bus_bits}b)")
    return bus_bits // elem_bits


def beats_for(n_elems: int, bus_bits: int, elem_bits: int) -> int:
    """Beats needed to carry ``n_elems`` densely packed elements."""
    if n_elems == 0:
        return 0
    return math.ceil(n_elems * elem_bits / bus_bits)


@dataclasses.dataclass(frozen=True)
class StreamDescriptor:
    """Base class for stream descriptors.

    Attributes:
      base: element offset of the first element in the source array (in
        elements, mirroring the paper's bus-aligned semantics).
      elem_bits: element width in bits (AR/AW ``size`` field under AXI-Pack).
      count: number of elements in the stream (burst length × packing factor).
    """

    base: int
    elem_bits: int
    count: int

    kind: BurstKind = dataclasses.field(default=BurstKind.BASE, init=False)

    def element_offsets(self) -> np.ndarray:
        """Absolute element offsets touched by the stream, in stream order."""
        raise NotImplementedError

    @property
    def bytes(self) -> int:
        return self.count * self.elem_bits // 8


@dataclasses.dataclass(frozen=True)
class ContiguousStream(StreamDescriptor):
    """A plain AXI4 burst: ``count`` elements starting at ``base``."""

    def __post_init__(self):
        object.__setattr__(self, "kind", BurstKind.BASE)

    def element_offsets(self) -> np.ndarray:
        return self.base + np.arange(self.count, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class StridedStream(StreamDescriptor):
    """A packed strided burst: elements at ``base + k*stride``.

    ``stride`` is in elements, like the user-field stride of AXI-Pack. A
    stride of 1 degenerates to a contiguous burst and is routed to the base
    converter (the paper's never-slower-than-AXI4 guarantee).
    """

    stride: int = 1

    def __post_init__(self):
        object.__setattr__(
            self, "kind", BurstKind.BASE if self.stride == 1 else BurstKind.STRIDED
        )

    def element_offsets(self) -> np.ndarray:
        return self.base + self.stride * np.arange(self.count, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class IndirectStream(StreamDescriptor):
    """A packed indirect burst: elements at ``base + index[k]``.

    The index array lives in memory (``indices``), matching the new
    ``vlimxei``/``vsimxei`` in-memory indexed instructions: indirection is
    resolved at the endpoint, so indices never consume core-side bandwidth.

    Attributes:
      indices: int array of ``count`` element offsets (relative to ``base``).
      index_bits: index element width (8/16/32), which sets the element:index
        ratio r and the r/(r+1) utilization ceiling of §III-E.
      remap_only: the stream repoints table entries without moving element
        payload (prefix sharing): only the index fetch touches memory.  The
        element fields still describe the pages being reused, so accounting
        can value the remap, but simulators must drain just the index lines.
    """

    indices: Optional[np.ndarray] = None
    index_bits: int = 32
    remap_only: bool = False

    def __post_init__(self):
        object.__setattr__(self, "kind", BurstKind.INDIRECT)
        if self.indices is None:
            raise ValueError("IndirectStream requires an index array")
        idx = np.asarray(self.indices)
        if idx.ndim != 1 or idx.shape[0] != self.count:
            raise ValueError(
                f"index array shape {idx.shape} does not match count={self.count}"
            )

    @property
    def ratio(self) -> float:
        """Element:index size ratio r — sets the r/(r+1) packing ceiling."""
        return self.elem_bits / self.index_bits

    @property
    def index_bytes(self) -> int:
        return self.count * self.index_bits // 8

    def element_offsets(self) -> np.ndarray:
        return self.base + np.asarray(self.indices, dtype=np.int64)


def page_table_streams(
    page_table,
    lengths,
    page_size: int,
    token_bytes: int,
    index_bits: int = 32,
    kv_elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Tuple["IndirectStream", ...]:
    """Batched indirect-stream descriptors for a paged-KV decode step.

    A paged KV cache is the serving-side instance of the paper's indirect
    stream: the *element* is one physical KV page (``page_size`` tokens ×
    the packed per-token width), and the per-sequence page-table row is the
    memory-resident index vector.  One :class:`IndirectStream` is returned
    per sequence with a non-zero length, covering exactly the pages a decode
    step touches (``ceil(len / page_size)`` leading table entries).

    ``token_bytes`` is the FP32-equivalent per-token footprint;
    ``kv_elem_bits`` the real element width of the pool on the stream.
    Narrow elements shrink the page element
    (:func:`repro.core.packing.packed_token_bytes`): an int8 pool's page
    descriptor carries a quarter of the fp32 bits plus the scale sideband —
    the ``elements_per_beat`` packing factor quadrupling, visible in the
    descriptor itself.

    The scheduler builds these descriptors each step and derives both the
    kernel operands (page ids / lengths) and the
    :func:`repro.core.packing.paged_decode_traffic` accounting from them, so
    the serving path and the Fig. 3 bus model share one source of truth.
    """
    from .packing import packed_token_bytes

    pt = np.asarray(page_table)
    lens = np.asarray(lengths)
    elem_bits = page_size * packed_token_bytes(
        token_bytes, kv_elem_bits, scale_bytes_per_token
    ) * 8
    out = []
    for row, ln in zip(pt, lens):
        n = -(-int(ln) // page_size)
        if n == 0:
            continue
        out.append(
            IndirectStream(
                base=0,
                elem_bits=elem_bits,
                count=n,
                indices=np.asarray(row[:n], dtype=np.int64),
                index_bits=index_bits,
            )
        )
    return tuple(out)


def prefill_table_streams(
    page_table,
    starts,
    counts,
    page_size: int,
    token_bytes: int,
    index_bits: int = 32,
    kv_elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Tuple["IndirectStream", ...]:
    """Batched indirect-stream descriptors for one chunked-prefill step.

    The prefill-side sibling of :func:`page_table_streams`: per sequence
    with a non-zero chunk, *two* indirect streams whose element is one
    physical KV page —

    * the **context read**: the leading ``ceil((start+count)/page)`` table
      entries the ``paged_prefill_attention`` kernel walks (its scalar-
      prefetch index vector, verbatim), and
    * the **chunk write**: the entries covering positions
      ``start .. start+count-1`` that ``paged_kv_write_chunk`` scatters
      through.

    ``kv_elem_bits``/``scale_bytes_per_token`` shrink the page element for
    narrow (int8) pools exactly as in :func:`page_table_streams`.

    Page math is shared with :func:`repro.core.packing.paged_prefill_traffic`
    via :func:`repro.core.packing.prefill_page_counts`, so the descriptors,
    the byte accounting, and the kernel's DMA walk are one source of truth.
    """
    from .packing import packed_token_bytes, prefill_page_counts

    pt = np.asarray(page_table)
    st = np.asarray(starts)
    ct = np.asarray(counts)
    ctx, chunk = prefill_page_counts(st, ct, page_size)
    elem_bits = page_size * packed_token_bytes(
        token_bytes, kv_elem_bits, scale_bytes_per_token
    ) * 8
    out = []
    for row, s, n, nc, nw in zip(pt, st, ct, ctx, chunk):
        if n == 0:
            continue
        out.append(
            IndirectStream(
                base=0,
                elem_bits=elem_bits,
                count=int(nc),
                indices=np.asarray(row[: int(nc)], dtype=np.int64),
                index_bits=index_bits,
            )
        )
        p_lo = int(s) // page_size
        out.append(
            IndirectStream(
                base=0,
                elem_bits=elem_bits,
                count=int(nw),
                indices=np.asarray(
                    row[p_lo : p_lo + int(nw)], dtype=np.int64
                ),
                index_bits=index_bits,
            )
        )
    return tuple(out)


def verify_table_streams(
    page_table,
    lengths,
    scored,
    page_size: int,
    token_bytes: int,
    index_bits: int = 32,
    kv_elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Tuple["IndirectStream", ...]:
    """Indirect-stream descriptors for one speculative K-token verify step.

    A verify chunk is a causal prefill chunk appended at the context tail,
    so the descriptors *are* :func:`prefill_table_streams` with
    ``starts = lengths`` — per active row one context-read stream over the
    leading ``ceil((length + scored)/page)`` table entries (the single
    clamped walk ``paged_verify`` amortizes over all K queries, where plain
    decode would emit ``scored`` separate walks) and one chunk-write stream
    over the pages the K appended tokens land in.  Shares its page math
    with :func:`repro.core.packing.spec_verify_traffic` through
    :func:`repro.core.packing.prefill_page_counts`.
    """
    return prefill_table_streams(
        page_table, lengths, scored, page_size, token_bytes,
        index_bits=index_bits, kv_elem_bits=kv_elem_bits,
        scale_bytes_per_token=scale_bytes_per_token,
    )


def share_table_streams(
    page_ids: Sequence[int],
    page_size: int,
    token_bytes: int,
    index_bits: int = 32,
    kv_elem_bits: int = 32,
    scale_bytes_per_token: int = 0,
) -> Tuple["IndirectStream", ...]:
    """Descriptor for mapping an already-resident prompt prefix (dedup).

    The admission-time sibling of :func:`page_table_streams`: when a new
    request's page-aligned prompt prefix is already in the pool, the only
    memory operation is fetching the ``len(page_ids)`` table entries being
    repointed — no KV payload moves.  The returned stream is ``remap_only``;
    its element fields still carry the packed page width so the byte value
    of the reuse (:func:`repro.core.packing.prefix_share_traffic`) and the
    descriptor agree on what was deduplicated.
    """
    from .packing import packed_token_bytes

    if not len(page_ids):
        return ()
    elem_bits = page_size * packed_token_bytes(
        token_bytes, kv_elem_bits, scale_bytes_per_token
    ) * 8
    return (
        IndirectStream(
            base=0,
            elem_bits=elem_bits,
            count=len(page_ids),
            indices=np.asarray(page_ids, dtype=np.int64),
            index_bits=index_bits,
            remap_only=True,
        ),
    )


def recurrent_state_streams(
    slots: Sequence[int],
    batch: int,
    n_layers: int,
    row_bytes: Sequence[int],
) -> Tuple["StridedStream", ...]:
    """Strided read-modify-write descriptors for one recurrent decode step.

    The strided-burst sibling of :func:`page_table_streams`: recurrent
    (RWKV/Mamba) serving state is fixed-size per sequence and lives in
    pools of shape ``(n_layers, batch, *row)``.  Flattened to
    ``(n_layers × batch)`` rows, one sequence's state sits at rows
    ``slot, slot + batch, slot + 2·batch, …`` — a textbook strided stream:
    ``base = slot``, ``stride = batch``, ``count = n_layers``, with the
    whole per-layer row as the element.  No memory-resident index vector
    exists; the stride in the request descriptor is the entire addressing
    metadata (the ``pack``/``indir=0`` encoding of the paper).

    A decode step both reads and writes the state, so *two* descriptors
    are emitted per (active slot, state tensor): the read burst and the
    write-back burst.  ``row_bytes`` carries one per-layer row footprint
    per state tensor (RWKV6 has one — the (H, 64, 64) wkv state; Mamba has
    two — the SSM state and the conv tail).

    With ``batch == 1`` the stride degenerates to 1 and the descriptor
    routes to the BASE converter (the never-slower-than-AXI4 guarantee),
    exactly like :class:`StridedStream` always does.

    The family builds these each step and derives the
    :func:`repro.core.packing.recurrent_decode_traffic` accounting from the
    same (slots, batch, layers, bytes) quantities, so descriptors and byte
    accounting share one source of truth — mirroring the paged path.
    """
    out = []
    for slot in slots:
        for rb in row_bytes:
            for _ in range(2):  # read burst + write-back burst
                out.append(
                    StridedStream(
                        base=int(slot),
                        elem_bits=int(rb) * 8,
                        count=int(n_layers),
                        stride=int(batch),
                    )
                )
    return tuple(out)


def word_addresses(
    stream: StreamDescriptor, word_bits: int = 32
) -> np.ndarray:
    """Map a stream's element offsets to memory *word* addresses.

    The banked controller operates on W-bit words (the bank width); an element
    smaller than a word still occupies one word access, while an element
    spanning multiple words issues several.  Returns the flat sequence of word
    addresses in stream order (used by the bank-conflict simulator).
    """
    offs = stream.element_offsets()
    if stream.elem_bits <= word_bits:
        scale = word_bits // stream.elem_bits
        return offs // scale
    words_per_elem = stream.elem_bits // word_bits
    base_words = offs * words_per_elem
    return (base_words[:, None] + np.arange(words_per_elem)[None, :]).reshape(-1)
