"""Cycle-approximate banked-memory controller simulator (reproduces Fig. 5).

Models the paper's proof-of-concept AXI-Pack endpoint: an adapter translating
packed bursts into sequences of ``n_ports`` parallel word accesses into ``m``
interleaved banks through an n×m crossbar, with per-lane decoupling queues, a
request regulator, and a beat packer.  For indirect bursts, the index stage
and element stage share the word ports through round-robin arbitration, and
element addresses only become available once their index line has been
fetched — exactly the structure of Fig. 2c/2d.

The simulator is the source of PACK-side bank-conflict stalls for the bus
model, and directly reproduces the parameter-sensitivity results of §III-E:

* utilization rises monotonically with bank count (fewer conflicts);
* prime bank counts beat powers of two on strided accesses (stride patterns
  alias modulo 2^k) but show no inherent advantage on indirect accesses;
* larger elements reduce strided conflicts (fewer aligned elements per line);
* indirect utilization is capped at r/(r+1) by index-line port sharing.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import List, Optional, Sequence

import numpy as np

from .streams import (
    BurstKind,
    IndirectStream,
    StreamDescriptor,
    StridedStream,
    word_addresses,
)

__all__ = [
    "BankConfig",
    "SimResult",
    "simulate_words",
    "simulate_stream",
    "strided_utilization",
    "indirect_utilization",
    "crossbar_area_kge",
]


@dataclasses.dataclass(frozen=True)
class BankConfig:
    """Endpoint parameters (defaults = the paper's PACK system: 8×17)."""

    n_ports: int = 8          # word ports (= bus_bits / word_bits)
    n_banks: int = 17         # paper's chosen area/perf tradeoff point
    word_bits: int = 32
    queue_depth: int = 4      # decoupling queue depth (32 in §III-E sweeps)
    ideal: bool = False       # conflict-free memory (the 'ideal' curves)


@dataclasses.dataclass
class SimResult:
    cycles: int
    data_beats: int
    utilization: float        # data beats delivered / cycles
    stall_cycles: int         # cycles - ideal cycles


def _bank_of(addr: np.ndarray, n_banks: int) -> np.ndarray:
    return addr % n_banks


def simulate_words(
    word_addrs: np.ndarray,
    cfg: BankConfig,
    index_lines: int = 0,
    words_per_index_line: Optional[int] = None,
    elems_per_index_line: Optional[int] = None,
) -> SimResult:
    """Simulate draining a word-address sequence through the banked endpoint.

    ``word_addrs`` is the element-stage word sequence in stream order; word k
    is issued on lane ``k % n_ports`` (the adapter fetches n words per beat in
    parallel).  If ``index_lines > 0``, an index stage sharing the ports is
    simulated: element addresses of group g unlock only after index line g
    completes, and index/element requests arbitrate round-robin per port.
    """
    n = cfg.n_ports
    words = np.asarray(word_addrs, dtype=np.int64)
    total_words = words.shape[0]
    total_beats = math.ceil(total_words / n)

    if cfg.ideal:
        # One beat per cycle, no conflicts, indices fetched magically.
        cycles = total_beats
        return SimResult(cycles, total_beats, 1.0, 0)

    banks = _bank_of(words, cfg.n_banks)

    # Per-lane element request FIFOs (lane k serves words k, k+n, ...).
    lane_req: List[deque] = [deque() for _ in range(n)]
    # Index-stage request FIFOs (contiguous lines, one word per lane each).
    idx_req: List[deque] = [deque() for _ in range(n)]

    if index_lines > 0:
        epl = elems_per_index_line or n
        # Index lines are contiguous in memory: line g occupies words
        # [g*n, (g+1)*n) of the index array (own address space, interleaved
        # the same way across banks).
        unlock_at_word = [min((g + 1) * epl, total_words) for g in range(index_lines)]
        locked_from = 0  # element words >= this are locked
    else:
        unlock_at_word = []
        locked_from = total_words

    # Pre-split element words into lanes, tracking global word order so we
    # can respect index-unlock boundaries.
    next_word = 0                      # next element word to enqueue
    lanes_filled = 0
    lane_occupancy = [0] * n           # served-but-unpacked words per lane
    served = np.zeros(total_words, dtype=bool)
    next_pack = 0                      # next beat index to pack
    packed_words = 0
    idx_line_issued = 0
    idx_line_done = [0] * max(index_lines, 1)
    idx_words_left: List[int] = []     # outstanding words per in-flight line
    pending_unlocks = deque()

    # Unlock initial element words (everything if no index stage).
    unlocked_until = total_words if index_lines == 0 else 0

    rng_priority = 0  # round-robin bank arbitration pointer
    stage_pref = 0    # round-robin between index (0) and element (1) stages

    cycles = 0
    max_cycles = 64 * (total_words + index_lines * n) + 1024
    idx_inflight: deque = deque()  # (words_remaining, line_id)

    while packed_words < total_words:
        cycles += 1
        if cycles > max_cycles:
            raise RuntimeError("bank simulator failed to converge")

        # --- refill lane request queues from the unlocked element stream ---
        while next_word < unlocked_until and len(lane_req[next_word % n]) < 64:
            lane_req[next_word % n].append(next_word)
            next_word += 1

        # --- index stage: keep one line in flight per free slot -----------
        while (
            index_lines
            and idx_line_issued < index_lines
            and len(idx_inflight) < 4
        ):
            for lane in range(n):
                idx_req[lane].append(idx_line_issued)  # one word per lane
            idx_inflight.append([n, idx_line_issued])
            idx_line_issued += 1

        # --- crossbar arbitration: one grant per bank per cycle -----------
        bank_busy = set()
        grants_elem: List[int] = []
        grants_idx: List[int] = []
        for lane_off in range(n):
            lane = (lane_off + rng_priority) % n
            # Round-robin between stages when both have pending requests.
            choices = []
            if idx_req[lane]:
                choices.append("idx")
            if lane_req[lane] and lane_occupancy[lane] < cfg.queue_depth:
                choices.append("elem")
            if not choices:
                continue
            if len(choices) == 2:
                choice = choices[stage_pref % 2]
            else:
                choice = choices[0]
            if choice == "idx":
                # Index lines are contiguous: word g*n+lane → bank.
                line = idx_req[lane][0]
                bank = (line * n + lane) % cfg.n_banks
                if bank in bank_busy:
                    continue
                bank_busy.add(bank)
                idx_req[lane].popleft()
                grants_idx.append(line)
            else:
                w = lane_req[lane][0]
                bank = int(banks[w])
                if bank in bank_busy:
                    continue
                bank_busy.add(bank)
                lane_req[lane].popleft()
                served[w] = True
                lane_occupancy[lane] += 1
                grants_elem.append(w)
        rng_priority = (rng_priority + 1) % n
        stage_pref ^= 1

        # --- index line completion unlocks element addresses --------------
        for line in grants_idx:
            for rec in idx_inflight:
                if rec[1] == line:
                    rec[0] -= 1
        while idx_inflight and idx_inflight[0][0] == 0:
            _, line = idx_inflight.popleft()
            unlocked_until = unlock_at_word[line]

        # --- beat packer: pop one complete beat per cycle ------------------
        beat_lo = next_pack * n
        beat_hi = min(beat_lo + n, total_words)
        if beat_lo < total_words and served[beat_lo:beat_hi].all():
            for w in range(beat_lo, beat_hi):
                lane_occupancy[w % n] -= 1
            packed_words += beat_hi - beat_lo
            next_pack += 1

    ideal_cycles = total_beats
    return SimResult(
        cycles=cycles,
        data_beats=total_beats,
        utilization=total_beats / cycles,
        stall_cycles=cycles - ideal_cycles,
    )


def simulate_stream(stream: StreamDescriptor, cfg: BankConfig) -> SimResult:
    """Simulate one packed stream through the endpoint."""
    if getattr(stream, "remap_only", False):
        # Prefix-sharing remap: no element payload crosses the endpoint —
        # only the contiguous index-line fetch (the table entries being
        # repointed) drains through the banks.
        assert isinstance(stream, IndirectStream)
        n_words = math.ceil(stream.count * stream.index_bits / cfg.word_bits)
        return simulate_words(np.arange(n_words, dtype=np.int64), cfg)
    words = word_addresses(stream, cfg.word_bits)
    if stream.kind is BurstKind.INDIRECT:
        assert isinstance(stream, IndirectStream)
        bus_bits = cfg.n_ports * cfg.word_bits
        idx_per_line = bus_bits // stream.index_bits
        n_lines = math.ceil(stream.count / idx_per_line)
        elems_per_line = idx_per_line * max(1, stream.elem_bits // cfg.word_bits)
        return simulate_words(
            words,
            cfg,
            index_lines=n_lines,
            elems_per_index_line=elems_per_line,
        )
    return simulate_words(words, cfg)


def strided_utilization(
    stride: int,
    cfg: BankConfig,
    elem_bits: int = 32,
    burst_len: int = 256,
) -> float:
    """Bus utilization for one strided read burst (Fig. 5b protocol)."""
    s = StridedStream(base=0, elem_bits=elem_bits, count=burst_len, stride=stride)
    return simulate_stream(s, cfg).utilization


def indirect_utilization(
    cfg: BankConfig,
    elem_bits: int = 32,
    index_bits: int = 32,
    burst_len: int = 256,
    addr_space: int = 1 << 16,
    seed: int = 0,
) -> float:
    """Bus utilization for one random-index indirect read burst (Fig. 5a)."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, addr_space, size=burst_len)
    s = IndirectStream(
        base=0, elem_bits=elem_bits, count=burst_len, indices=idx, index_bits=index_bits
    )
    return simulate_stream(s, cfg).utilization


# ---------------------------------------------------------------------------
# Crossbar area model (Fig. 5c analogue).
#
# The n×m crossbar's datapath grows with n*m*word_bits; bank address
# computation is a cheap mask for power-of-two counts but needs modulo and
# division units for prime counts, whose relative overhead shrinks as the
# datapath grows.  Constants are calibrated once against the paper's reported
# 8-port/32-bit design points (≈55 kGE at 16 banks pow2, with prime overhead
# decreasing from ~40 % at 11 banks to ~15 % at 31 banks).
# ---------------------------------------------------------------------------

_XBAR_KGE_PER_PORTBANKBIT = 55.0 / (8 * 16 * 32)
_MODDIV_KGE_PER_PORT = 2.6  # one modulo + division unit per port


def _is_pow2(x: int) -> bool:
    return x & (x - 1) == 0


def crossbar_area_kge(n_ports: int, n_banks: int, word_bits: int = 32) -> float:
    """Analytic kGE estimate of the n×m bank crossbar (Fig. 5c analogue)."""
    area = _XBAR_KGE_PER_PORTBANKBIT * n_ports * n_banks * word_bits
    if not _is_pow2(n_banks):
        area += _MODDIV_KGE_PER_PORT * n_ports
    return area
