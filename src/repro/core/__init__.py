"""Core packed-irregular-stream library (the paper's contribution, in JAX).

Public surface:

* :mod:`repro.core.streams` -- stream descriptors (the AXI-Pack request form).
* :mod:`repro.core.packing` -- functional pack/unpack semantics + traffic
  accounting (the reference semantics of the beat packer).
* :mod:`repro.core.busmodel` -- analytical BASE/PACK/IDEAL cycle model.
* :mod:`repro.core.banksim` -- cycle-approximate banked endpoint simulator.
"""
from .streams import (
    BurstKind,
    ContiguousStream,
    IndirectStream,
    StridedStream,
    beats_for,
    elements_per_beat,
    page_table_streams,
    prefill_table_streams,
    recurrent_state_streams,
    verify_table_streams,
)
from .packing import (
    Traffic,
    indirect_traffic,
    pack_indirect,
    pack_strided,
    packed_token_bytes,
    paged_decode_traffic,
    paged_prefill_traffic,
    prefill_page_counts,
    spec_verify_traffic,
    recurrent_decode_traffic,
    recurrent_prefill_traffic,
    strided_traffic,
    unpack_indirect,
    unpack_strided,
)
from .busmodel import (
    BusConfig,
    System,
    WorkloadModel,
    Iteration,
    indirect_utilization_ceiling,
    stream_cycles,
)
from .banksim import (
    BankConfig,
    SimResult,
    crossbar_area_kge,
    indirect_utilization,
    simulate_stream,
    strided_utilization,
)
