"""Analytical bus-packing performance model (reproduces Fig. 3 / Fig. 5 laws).

The paper evaluates three systems on a D-bit AXI bus:

* **BASE** — stock AXI4.  Contiguous accesses burst at full width; strided and
  indirect accesses issue one *narrow* beat per element (utilization e/D).
  Indices for indirect accesses are fetched to the core as contiguous vector
  loads (packed), then spent issuing element requests.
* **PACK** — AXI-Pack.  Strided and indirect elements are densely packed onto
  the bus by the memory-side controller (utilization → 1, minus bank-conflict
  stalls and iteration overhead).  Indirection is resolved at the endpoint;
  index fetches share the controller's n word ports with element fetches,
  which caps indirect bus utilization at r/(r+1) for element:index ratio r.
* **IDEAL** — per-lane ideal memory: packed, conflict-free, but indices still
  transit to the core (the paper measures up to 20 % of spmv bus time there).

This module turns :mod:`repro.core.streams` descriptors into cycle and beat
counts for each system.  It is deliberately simple — a handful of documented
constants shared by *all* benchmarks — because its job is to reproduce the
paper's measured laws from first principles, not to curve-fit each workload.

Cycle model (per stream phase, R/W channel):
  BASE  contiguous: beats = ceil(N*e/D); strided/indirect: beats = N.
  PACK  beats = ceil(N*e/D); plus, for indirect, the element stage stalls
        ceil(N*i/D) port-cycles while the index stage occupies shared ports.
  IDEAL beats = ceil(N*e/D) (+ index transfer beats on the bus, for indirect).

On top of beats, a phase pays ``iter_overhead`` cycles per loop iteration
(address setup, AR issue, scoreboard) and — for PACK — bank-conflict stalls
taken from :mod:`repro.core.banksim` when a simulator is supplied.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .streams import (
    BurstKind,
    ContiguousStream,
    IndirectStream,
    StreamDescriptor,
    StridedStream,
    beats_for,
)

__all__ = [
    "BusConfig",
    "PhaseCost",
    "System",
    "stream_cycles",
    "WorkloadModel",
    "indirect_utilization_ceiling",
]


@dataclasses.dataclass(frozen=True)
class BusConfig:
    """Static system parameters (defaults = the paper's PACK system)."""

    bus_bits: int = 256          # D: data bus width
    word_bits: int = 32          # W: bank/word width
    lanes: int = 8               # vector lanes (= bus_bits/word_bits for Ara)
    iter_overhead: float = 5.0   # cycles of loop/issue overhead per iteration
    reduction_latency: float = 18.0  # cross-lane reduction tree (cal.: gemv-row 37%)
    # BASE narrow-access cost per element.  Strided loads serialize address
    # generation + AR issue (~2 cyc/elem); indexed loads pipeline through the
    # already-loaded index registers (~1 cyc/elem).  Calibrated once against
    # Fig. 3a's ismt (5.4×) and spmv (2.4×) and reused for all workloads.
    base_strided_cpe: float = 2.0
    base_indirect_cpe: float = 1.0

    @property
    def words_per_beat(self) -> int:
        return self.bus_bits // self.word_bits


class System:
    BASE = "base"
    PACK = "pack"
    IDEAL = "ideal"
    ALL = (BASE, PACK, IDEAL)


@dataclasses.dataclass
class PhaseCost:
    """Cycle/beat cost of one stream or compute phase."""

    cycles: float = 0.0
    data_beats: int = 0      # bus beats carrying useful stream data
    index_beats: int = 0     # bus beats carrying indices (BASE/IDEAL only)
    bytes_data: int = 0
    bytes_index: int = 0

    def __add__(self, o: "PhaseCost") -> "PhaseCost":
        return PhaseCost(
            self.cycles + o.cycles,
            self.data_beats + o.data_beats,
            self.index_beats + o.index_beats,
            self.bytes_data + o.bytes_data,
            self.bytes_index + o.bytes_index,
        )


def indirect_utilization_ceiling(elem_bits: int, index_bits: int) -> float:
    """The r/(r+1) law of §III-E: ideal indirect bus utilization."""
    r = elem_bits / index_bits
    return r / (r + 1.0)


def stream_cycles(
    stream: StreamDescriptor,
    system: str,
    cfg: BusConfig,
    conflict_stalls: float = 0.0,
) -> PhaseCost:
    """Cycles and beats to move one stream through the given system.

    ``conflict_stalls`` are extra PACK-side cycles from the bank simulator
    (zero for IDEAL; BASE's narrow accesses are port-rate-limited already).
    """
    n, e, d = stream.count, stream.elem_bits, cfg.bus_bits
    packed_beats = beats_for(n, d, e)
    cost = PhaseCost(bytes_data=stream.bytes)

    if stream.kind is BurstKind.BASE:
        # Contiguous bursts are identical on all three systems.
        cost.data_beats = packed_beats
        cost.cycles = packed_beats + (conflict_stalls if system == System.PACK else 0.0)
        return cost

    if stream.kind is BurstKind.STRIDED:
        if system == System.BASE:
            # One narrow beat per element: the bus carries e of D useful bits.
            cost.data_beats = n
            cost.cycles = float(n) * cfg.base_strided_cpe
        elif system == System.PACK:
            cost.data_beats = packed_beats
            cost.cycles = packed_beats + conflict_stalls
        else:  # IDEAL
            cost.data_beats = packed_beats
            cost.cycles = float(packed_beats)
        return cost

    assert isinstance(stream, IndirectStream)
    i = stream.index_bits
    index_line_beats = beats_for(n, d, i)
    cost.bytes_index = stream.index_bytes
    if system == System.BASE:
        # Indices stream to the core as a contiguous (packed) load, then each
        # element is fetched with a narrow beat.
        cost.index_beats = index_line_beats
        cost.data_beats = n
        cost.cycles = float(index_line_beats) + n * cfg.base_indirect_cpe
    elif system == System.PACK:
        # Indices are fetched endpoint-side as whole lines; the index stage
        # shares the n word ports with the element stage (round-robin), so
        # every index line steals one beat-time from element packing: the
        # r/(r+1) ceiling.  Indices never appear on the bus.
        cost.data_beats = packed_beats
        cost.cycles = packed_beats + index_line_beats + conflict_stalls
    else:  # IDEAL: packed conflict-free elements, but indices cross the bus.
        cost.index_beats = index_line_beats
        cost.data_beats = packed_beats
        cost.cycles = float(packed_beats + index_line_beats)
    return cost


def compute_cycles(n_ops: int, cfg: BusConfig) -> float:
    """Cycles for n_ops element-wise vector ops on ``cfg.lanes`` lanes."""
    return math.ceil(n_ops / cfg.lanes)


def reduction_cycles(n_elems: int, cfg: BusConfig) -> float:
    """Cycles for a full vector reduction (lane-serial + tree latency).

    Models Ara's costly cross-lane reductions that make row-wise gemv
    bandwidth-poor (37 % utilization in Fig. 3b).
    """
    return math.ceil(n_elems / cfg.lanes) + cfg.reduction_latency


@dataclasses.dataclass
class Iteration:
    """One loop iteration of a workload: streams moved + compute performed.

    ``streams`` move concurrently with compute (decoupled VLSU): iteration
    time is max(memory time, compute time) + fixed iteration overhead, which
    matches the converging speedup curves of Fig. 3d/e.
    """

    streams: Sequence[StreamDescriptor] = ()
    compute_ops: int = 0
    reductions: int = 0
    reduction_width: int = 0
    serialize: bool = False  # read-write ordering (e.g. ismt swap) serializes
    repeats: int = 1


@dataclasses.dataclass
class WorkloadResult:
    name: str
    system: str
    cycles: float
    data_beats: int
    index_beats: int
    bytes_data: int
    bytes_index: int
    bus_util: float           # useful data beats / total bus-busy cycles
    bus_util_with_index: float

    def speedup_over(self, other: "WorkloadResult") -> float:
        return other.cycles / self.cycles


class WorkloadModel:
    """A benchmark expressed as iterations; evaluated under BASE/PACK/IDEAL."""

    def __init__(
        self,
        name: str,
        iterations: Sequence[Iteration],
        cfg: Optional[BusConfig] = None,
        conflict_fn: Optional[Callable[[StreamDescriptor], float]] = None,
    ):
        self.name = name
        self.iterations = list(iterations)
        self.cfg = cfg or BusConfig()
        # conflict_fn(stream) -> extra PACK stall cycles (from banksim).
        self.conflict_fn = conflict_fn or (lambda s: 0.0)

    def evaluate(self, system: str) -> WorkloadResult:
        cfg = self.cfg
        total = PhaseCost()
        for it in self.iterations:
            mem = PhaseCost()
            for s in it.streams:
                stalls = self.conflict_fn(s) if system == System.PACK else 0.0
                mem = mem + stream_cycles(s, system, cfg, stalls)
            comp = compute_cycles(it.compute_ops, cfg)
            if it.reductions:
                comp += it.reductions * reduction_cycles(it.reduction_width, cfg)
            if it.serialize:
                cycles = mem.cycles + comp + cfg.iter_overhead
            else:
                cycles = max(mem.cycles, comp) + cfg.iter_overhead
            iter_cost = PhaseCost(
                cycles=cycles,
                data_beats=mem.data_beats,
                index_beats=mem.index_beats,
                bytes_data=mem.bytes_data,
                bytes_index=mem.bytes_index,
            )
            for _ in range(it.repeats):
                total = total + iter_cost
        util = total.data_beats / total.cycles if total.cycles else 0.0
        util_w_idx = (
            (total.data_beats + total.index_beats) / total.cycles
            if total.cycles
            else 0.0
        )
        return WorkloadResult(
            name=self.name,
            system=system,
            cycles=total.cycles,
            data_beats=total.data_beats,
            index_beats=total.index_beats,
            bytes_data=total.bytes_data,
            bytes_index=total.bytes_index,
            bus_util=util,
            bus_util_with_index=util_w_idx,
        )

    def evaluate_all(self) -> dict:
        return {s: self.evaluate(s) for s in System.ALL}
