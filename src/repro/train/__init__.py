"""Training: step factories (SPMD + compressed manual-DP), microbatching."""
from .train_step import make_train_step, make_compressed_train_step, make_loss_fn
