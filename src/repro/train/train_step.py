"""Train-step factory: microbatch accumulation, optional int8-compressed DP.

Two step builders:

* ``make_train_step``            — SPMD (pjit) path: batch sharded over
  (pod, data), gradient reduction emitted by XLA (reduce-scatter under FSDP).
* ``make_compressed_train_step`` — manual-DP path: ``shard_map`` manual over
  (pod, data) with the model axis left automatic; the gradient all-reduce is
  the int8 error-feedback collective (optim.compression), 4× fewer wire
  bytes.  For non-FSDP configs (params replicated across DP).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.optim.optimizers import Optimizer
from repro.optim import compression
from repro.parallel.sharding import ShardingRules, make_rules


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree_util.tree_map(sp, batch)


def make_loss_fn(cfg: ArchConfig, rules: ShardingRules):
    def loss_fn(params, batch):
        return lm.train_loss(params, batch, cfg, rules)

    return loss_fn


def grads_with_accum(loss_fn, params, batch, grad_accum: int):
    """Returns (mean loss, metrics, grads) with lax.scan microbatching."""
    if grad_accum == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    micro = _split_microbatches(batch, grad_accum)

    def step(carry, mb):
        acc, loss_sum = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree_util.tree_map(jnp.add, acc, g)
        return (acc, loss_sum + loss), None

    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc, loss_sum), _ = jax.lax.scan(step, (zeros, jnp.float32(0.0)), micro)
    grads = jax.tree_util.tree_map(lambda g: g / grad_accum, acc)
    loss = loss_sum / grad_accum
    return loss, {"ce_loss": loss}, grads


def make_train_step(
    cfg: ArchConfig,
    opt: Optimizer,
    rules: ShardingRules,
    grad_accum: int = 1,
) -> Callable:
    """(params, opt_state, batch, step) → (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg, rules)

    def train_step(params, opt_state, batch, step):
        loss, metrics, grads = grads_with_accum(loss_fn, params, batch, grad_accum)
        new_params, new_opt, opt_metrics = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, {"loss": loss, **metrics, **opt_metrics}

    return train_step


# ---------------------------------------------------------------------------
# Int8-compressed manual-DP step
# ---------------------------------------------------------------------------


def make_compressed_train_step(
    cfg: ArchConfig,
    opt: Optimizer,
    mesh: Mesh,
    dp_axes: Tuple[str, ...] = ("data",),
    grad_accum: int = 1,
) -> Callable:
    """Manual-DP train step with int8 error-feedback gradient all-reduce.

    Params must be replicated across ``dp_axes`` (cfg.fsdp=False).  The
    error-feedback residual rides in ``opt_state['err_fb']`` with a leading
    device dim sharded over the DP axes.
    """
    assert not cfg.fsdp, "compressed DP path requires replicated params"
    # Inside the manual region the batch is device-local: no batch constraint.
    inner_rules = make_rules(
        batch_axes=None, with_pod=False, shard_kv_heads=cfg.shard_kv_heads
    )
    loss_fn = make_loss_fn(cfg, inner_rules)
    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def local_step(params, opt_state, batch, step, err_fb):
        loss, metrics, grads = grads_with_accum(loss_fn, params, batch, grad_accum)
        loss = jax.lax.pmean(loss, axis)
        grads, new_err = compression.compressed_grad_psum(grads, axis, err_fb[0])
        n_dev = jax.lax.psum(1, axis)
        grads = jax.tree_util.tree_map(lambda g: g / n_dev, grads)
        new_params, new_opt, opt_metrics = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, new_err[None], {"loss": loss, **opt_metrics}

    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]

    from jax import shard_map

    batch_spec = P(axis)
    mapped = shard_map(
        local_step,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec, P(), P(axis)),
        out_specs=(P(), P(), P(axis), P()),
        check_vma=False,
    )

    def train_step(params, opt_state, batch, step, err_fb):
        return mapped(params, opt_state, batch, step, err_fb)

    train_step.init_err_fb = lambda params: jnp.zeros(
        (n_dp, compression.tree_size(params)), jnp.float32
    )
    return train_step
