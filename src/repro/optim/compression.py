"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

AXI-Pack's core move — pack *narrow* elements densely so the wide link always
carries useful bits — applied to the interconnect: gradients cross the
DP/pod axes as int8 (4× fewer bytes than fp32, 2× fewer than bf16), with
per-chunk scales and an error-feedback residual so compression noise
accumulates to zero instead of biasing the optimizer.

Protocol (inside ``shard_map``, manual over the DP axes):

  1. chunk-quantize ``g + err`` to int8 (per-128-element scales);
  2. ``all_to_all`` the int8 chunks (reduce-scatter's exchange phase);
  3. local dequant-sum in fp32;  4. requantize the reduced shard to int8;
  5. ``all_gather`` the int8 shards; 6. dequant; update ``err``.

Bytes on the wire per device: N int8 out + N int8 in ≈ N/2 of the bf16
ring all-reduce's ~2N — a 4× collective-byte reduction, visible in the
dry-run's collective table (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CHUNK = 128  # elements per quantization scale (one VREG lane row)


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (..., K*CHUNK) → (int8 same shape, scales (..., K))."""
    shp = x.shape
    xr = x.reshape(shp[:-1] + (shp[-1] // CHUNK, CHUNK)).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xr), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xr / scale), -127, 127).astype(jnp.int8)
    return q.reshape(shp), scale[..., 0].reshape(shp[:-1] + (shp[-1] // CHUNK,))


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    shp = q.shape
    qr = q.reshape(shp[:-1] + (shp[-1] // CHUNK, CHUNK)).astype(jnp.float32)
    return (qr * scale[..., None]).reshape(shp)


def _pad_to(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, (0, pad)) if pad else x


def int8_psum(x: jax.Array, axis_name, err: jax.Array):
    """Error-feedback int8 all-reduce of a flat fp32 vector (shard_map ctx).

    Returns (reduced (same shape, fp32), new_err).  ``err`` is the
    device-local residual from previous rounds (same shape as x).
    """
    n_dev = jax.lax.psum(1, axis_name)
    n = x.shape[0]
    xe = x + err
    flat = _pad_to(xe, n_dev * CHUNK)
    shard = flat.shape[0] // n_dev

    # 1-2) quantize + exchange (the reduce-scatter phase, int8 on the wire)
    q, s = _quantize(flat.reshape(n_dev, shard))
    q_x = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=False)
    s_x = jax.lax.all_to_all(s, axis_name, split_axis=0, concat_axis=0, tiled=False)

    # 3-4) local fp32 reduction of my shard, requantize
    local = jnp.sum(_dequantize(q_x, s_x), axis=0)          # (shard,)
    q2, s2 = _quantize(local[None])                          # (1, shard)

    # 5) all-gather int8 shards (the broadcast phase)
    qg = jax.lax.all_gather(q2[0], axis_name, axis=0)        # (n_dev, shard)
    sg = jax.lax.all_gather(s2[0], axis_name, axis=0)
    out = _dequantize(qg, sg).reshape(-1)[:n]

    # 6) error feedback: what quantization lost on MY contribution
    my_sent = _dequantize(q, s).reshape(-1)[:n]
    new_err = xe - my_sent
    return out, new_err


def tree_size(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


def flatten_tree(tree) -> Tuple[jax.Array, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, (treedef, [(l.shape, l.dtype) for l in leaves])


def unflatten_tree(flat: jax.Array, aux) -> Any:
    treedef, shapes = aux
    out, off = [], 0
    for shp, dt in shapes:
        n = int(np.prod(shp))
        out.append(flat[off : off + n].reshape(shp).astype(dt))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def compressed_grad_psum(grads, axis_name, err_flat: jax.Array):
    """int8-all-reduce an entire gradient pytree (flattened once)."""
    flat, aux = flatten_tree(grads)
    reduced, new_err = int8_psum(flat, axis_name, err_flat)
    return unflatten_tree(reduced, aux), new_err
