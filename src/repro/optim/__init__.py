"""Optimizers (AdamW/Adafactor) + int8 error-feedback gradient compression."""
from .optimizers import OptimizerConfig, make_optimizer, clip_by_global_norm, global_norm
from . import compression
