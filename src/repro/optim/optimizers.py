"""Optimizers: AdamW and Adafactor, with configurable state dtypes.

Pure-functional: ``init(params) → state``, ``update(grads, state, params,
step) → (new_params, new_state)``.  State pytrees mirror the parameter tree,
so the sharding specs derived for params apply leaf-wise to optimizer state
(ZeRO-style: with ``cfg.fsdp`` params — and hence states — are sharded over
data × model).

Adafactor (factored second moment, bf16 first moment) is what makes the
480B config fit: AdamW fp32 states for 480B ≈ 5.8 TB > a 256-chip pod's
4 TB HBM, while factored states are ~1 TB (see configs/arctic_480b.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # bfloat16 halves AdamW m/v bytes
    factored_threshold: int = 128     # adafactor: factor dims ≥ this


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _adamw(cfg)
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.name}")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw(cfg: OptimizerConfig) -> Optimizer:
    mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]
    lr_fn = cosine_schedule(cfg.lr, cfg.warmup_steps, cfg.total_steps)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=mdt)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(step)
        c1 = 1.0 - cfg.b1 ** t
        c2 = 1.0 - cfg.b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
            v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
            step_ = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
            decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim > 1 else 0.0
            p_new = p.astype(jnp.float32) - lr * (step_ + decay)
            return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        out = []
        dep = jnp.float32(0.0)
        for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
            g, dep = _chain(g, dep)
            p_new, m_new, v_new = upd(g, m, v, p)
            dep = _dep_of(p_new, dep)
            out.append((p_new, m_new, v_new))
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


# NB: an explicit update-serialization chain (g += prev_p_new[0]*0) was tried
# to bound concurrent f32 update temporaries; it interacted pathologically
# with the grad-accumulation scan (temp arena 36 GB → 626 GB on arctic) and
# was removed.  Kept as a no-op hook for future scheduling experiments.
_SERIAL_THRESHOLD = 1 << 62


def _chain(g, dep):
    if g.size >= _SERIAL_THRESHOLD:
        g = g + (dep * 0.0).astype(g.dtype)
    return g, dep


def _dep_of(p_new, dep):
    if p_new.size >= _SERIAL_THRESHOLD:
        return p_new.ravel()[0].astype(jnp.float32)
    return dep


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; bf16 first moment)
# ---------------------------------------------------------------------------


def _adafactor(cfg: OptimizerConfig) -> Optimizer:
    lr_fn = cosine_schedule(cfg.lr, cfg.warmup_steps, cfg.total_steps)

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= cfg.factored_threshold and p.shape[-2] >= cfg.factored_threshold

    def init(params):
        def st(p):
            if factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    "m": jnp.zeros_like(p, dtype=jnp.bfloat16),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32),
                    "m": jnp.zeros_like(p, dtype=jnp.bfloat16)}

        return jax.tree_util.tree_map(st, params)

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_fn(step)
        b2 = 1.0 - (jnp.asarray(step, jnp.float32) + 1.0) ** -0.8

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + 1e-30
            if factored(p):
                vr = b2 * s["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
                vc = b2 * s["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
                denom = (
                    vr[..., None]
                    * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], 1e-30)
                )
                pre = g32 * jax.lax.rsqrt(denom + 1e-30)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = b2 * s["v"] + (1 - b2) * g2
                pre = g32 * jax.lax.rsqrt(v + 1e-30)
                new_s = {"v": v}
            # update clipping (RMS ≤ 1) à la Adafactor
            rms = jnp.sqrt(jnp.mean(pre * pre) + 1e-30)
            pre = pre / jnp.maximum(1.0, rms)
            m = cfg.b1 * s["m"].astype(jnp.float32) + (1 - cfg.b1) * pre
            decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim > 1 else 0.0
            p_new = (p.astype(jnp.float32) - lr * (m + decay)).astype(p.dtype)
            new_s["m"] = m.astype(jnp.bfloat16)
            return p_new, new_s

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_s = tdef.flatten_up_to(state)
        flat_p = tdef.flatten_up_to(params)
        out = []
        dep = jnp.float32(0.0)
        for g, s, p in zip(flat_g, flat_s, flat_p):
            g, dep = _chain(g, dep)
            p_new, s_new = upd(g, s, p)
            dep = _dep_of(p_new, dep)
            out.append((p_new, s_new))
        new_p = tdef.unflatten([o[0] for o in out])
        new_s = tdef.unflatten([o[1] for o in out])
        return new_p, new_s, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)
