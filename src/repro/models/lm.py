"""Top-level language-model assembly for all assigned architecture families.

One parameter-def tree + three entry points (``train_loss``, ``prefill``,
``decode_step``) cover every family; layers are stacked and scanned
(``lax.scan``) so HLO size and compile time are depth-independent — required
for the 64-layer/480B dry-runs on this host.  Per-layer heterogeneity
(gemma3 local/global, hymba global layers) is a traced flag consumed inside
the scanned block.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ShardingRules, constrain
from .common import (
    Param,
    chunked_softmax_xent,
    init_params,
    map_params,
    rms_norm,
    stack_layer_defs,
)
from . import attention as attn_mod
from . import mlp as mlp_mod
from . import rwkv6 as rwkv_mod
from . import mamba as mamba_mod


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def layer_defs(cfg: ArchConfig, q_heads: int, kv_heads: int) -> Dict[str, Any]:
    d = cfg.d_model
    norms = {
        "ln1": Param((d,), ("d_model",), init="zeros"),
        "ln2": Param((d,), ("d_model",), init="zeros"),
    }
    if cfg.ssm == "rwkv6":
        return {**rwkv_mod.rwkv_defs(cfg, q_heads), **norms}
    if cfg.ssm == "hymba":
        return {
            "hymba": mamba_mod.hymba_defs(cfg, q_heads, kv_heads),
            "mlp": mlp_mod.mlp_defs(cfg),
            **norms,
        }
    block: Dict[str, Any] = {"attn": attn_mod.attention_defs(cfg, q_heads, kv_heads)}
    if cfg.n_experts:
        block["moe"] = mlp_mod.moe_defs(cfg)
    else:
        block["mlp"] = mlp_mod.mlp_defs(cfg)
    return {**block, **norms}


def model_defs(cfg: ArchConfig, tp: int = 1) -> Dict[str, Any]:
    q_heads, kv_heads = cfg.heads_for_tp(tp)
    if cfg.ssm == "rwkv6":
        q_heads = rwkv_mod.rwkv_heads(cfg, padded=tp > 1)
    vp = cfg.vocab_padded(tp)
    defs: Dict[str, Any] = {
        "layers": stack_layer_defs(layer_defs(cfg, q_heads, kv_heads), cfg.n_layers),
        "final_norm": Param((cfg.d_model,), ("d_model",), init="zeros"),
    }
    if cfg.modality != "audio":
        defs["embed"] = Param((vp, cfg.d_model), ("vocab", "d_model"), init="embed")
    if cfg.modality in ("audio", "vlm"):
        defs["frontend_proj"] = Param(
            (cfg.frontend_dim, cfg.d_model), ("frontend", "d_model")
        )
    if not cfg.tie_embeddings:
        defs["head"] = Param((cfg.d_model, vp), ("d_model", "vocab"))
    return defs


def init_model(cfg: ArchConfig, key: jax.Array, tp: int = 1):
    dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.param_dtype]
    return init_params(model_defs(cfg, tp), key, dtype)


MLP_WEIGHT_NAMES = ("w_up", "w_gate", "w_down")


def quantize_mlp_weights(params, cfg: ArchConfig):
    """w8a16: replace MLP/MoE weight leaves by {'q': int8, 'scale': f32}.

    Per-output-channel symmetric scales (axis=-2, the contraction dim, with
    keepdims so dequant broadcasts).  Serving-side narrow-element packing:
    halves resident weight bytes and the HBM stream per matmul — on
    qwen1.5-32b it removes the need for data-sharded MLP weights entirely
    (EXPERIMENTS.md §Perf A, iteration 4).
    """

    def walk(tree, in_mlp=False):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if in_mlp and k in MLP_WEIGHT_NAMES and hasattr(v, "dtype"):
                    amax = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-2,
                                   keepdims=True)
                    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
                    q = jnp.clip(jnp.round(v.astype(jnp.float32) / scale),
                                 -127, 127).astype(jnp.int8)
                    out[k] = {"q": q, "scale": scale.astype(jnp.float32)}
                else:
                    out[k] = walk(v, in_mlp or k in ("mlp", "moe", "dense"))
            return out
        return tree

    return walk(params)


def quantize_mlp_structs(sds_tree, spec_tree, cfg: ArchConfig):
    """Abstract (ShapeDtypeStruct, sharding-spec) version for the dry-run."""
    import dataclasses as _dc

    def walk(sds, spec, in_mlp=False):
        if isinstance(sds, dict):
            o1, o2 = {}, {}
            for k in sds:
                if in_mlp and k in MLP_WEIGHT_NAMES and hasattr(sds[k], "shape"):
                    shp = sds[k].shape
                    sshp = shp[:-2] + (1,) + shp[-1:]
                    o1[k] = {
                        "q": jax.ShapeDtypeStruct(shp, jnp.int8),
                        "scale": jax.ShapeDtypeStruct(sshp, jnp.float32),
                    }
                    # the contracted (-2) dim collapses to 1 in the scale:
                    # drop its mesh axis from the spec
                    wspec = spec[k]
                    from jax.sharding import NamedSharding, PartitionSpec as P
                    ps = list(wspec.spec) + [None] * (len(shp) - len(wspec.spec))
                    ps[len(shp) - 2] = None
                    sspec = NamedSharding(wspec.mesh, P(*ps))
                    o2[k] = {"q": wspec, "scale": sspec}
                else:
                    r1, r2 = walk(sds[k], spec[k],
                                  in_mlp or k in ("mlp", "moe", "dense"))
                    o1[k], o2[k] = r1, r2
            return o1, o2
        return sds, spec

    return walk(sds_tree, spec_tree)


def global_flags(cfg: ArchConfig) -> np.ndarray:
    """Per-layer is-global-attention flags (float for traced select)."""
    l = cfg.n_layers
    if cfg.ssm == "hymba":
        flags = np.zeros(l)
        flags[[0, l // 2, l - 1]] = 1.0
        return flags
    if cfg.global_interval is None:
        return np.ones(l)
    return np.array([float(cfg.layer_is_global(i)) for i in range(l)])


# ---------------------------------------------------------------------------
# Blocks (one scanned step per family)
# ---------------------------------------------------------------------------


def _block_train(p, x, cfg, rules, is_global, positions):
    """Returns (x, aux_loss)."""
    if cfg.ssm == "rwkv6":
        x, _ = rwkv_mod.rwkv_block(p, x, cfg, rules, p)
        return x, jnp.float32(0.0)
    if cfg.ssm == "hymba":
        h = mamba_mod.hymba_block_fwd(
            p["hymba"], rms_norm(x, p["ln1"]), cfg, rules, is_global, positions
        )
        x = x + h
        x = x + mlp_mod.mlp_fwd(p["mlp"], rms_norm(x, p["ln2"]), cfg, rules)
        return x, jnp.float32(0.0)
    a = attn_mod.attention_fwd(
        p["attn"], rms_norm(x, p["ln1"]), cfg, rules, is_global, positions
    )
    x = x + a
    if cfg.n_experts:
        m, aux = mlp_mod.moe_fwd(p["moe"], rms_norm(x, p["ln2"]), cfg, rules)
    else:
        m, aux = mlp_mod.mlp_fwd(p["mlp"], rms_norm(x, p["ln2"]), cfg, rules), jnp.float32(0.0)
    return x + m, aux


def _block_prefill(p, x, cfg, rules, is_global, cache):
    if cfg.ssm == "rwkv6":
        x, st = rwkv_mod.rwkv_block(p, x, cfg, rules, p, state=None)
        # prefill leaves the final state in the cache
        return x, st
    if cfg.ssm == "hymba":
        h, cache = mamba_mod.hymba_block_prefill(
            p["hymba"], rms_norm(x, p["ln1"]), cfg, rules, is_global, cache
        )
        x = x + h
        x = x + mlp_mod.mlp_fwd(p["mlp"], rms_norm(x, p["ln2"]), cfg, rules)
        return x, cache
    a, cache = attn_mod.attention_prefill(
        p["attn"], rms_norm(x, p["ln1"]), cfg, rules, is_global, cache
    )
    x = x + a
    if cfg.n_experts:
        m, _ = mlp_mod.moe_fwd(p["moe"], rms_norm(x, p["ln2"]), cfg, rules)
    else:
        m = mlp_mod.mlp_fwd(p["mlp"], rms_norm(x, p["ln2"]), cfg, rules)
    return x + m, cache


def _block_decode(p, x, cfg, rules, is_global, cache, pos):
    if cfg.ssm == "rwkv6":
        return rwkv_mod.rwkv_block(p, x, cfg, rules, p, state=cache)
    if cfg.ssm == "hymba":
        h, cache = mamba_mod.hymba_block_decode(
            p["hymba"], rms_norm(x, p["ln1"]), cfg, rules, is_global, cache, pos
        )
        x = x + h
        x = x + mlp_mod.mlp_fwd(p["mlp"], rms_norm(x, p["ln2"]), cfg, rules)
        return x, cache
    a, cache = attn_mod.attention_decode(
        p["attn"], rms_norm(x, p["ln1"]), cfg, rules, is_global, cache, pos
    )
    x = x + a
    if cfg.n_experts:
        m, _ = mlp_mod.moe_fwd(p["moe"], rms_norm(x, p["ln2"]), cfg, rules)
    else:
        m = mlp_mod.mlp_fwd(p["mlp"], rms_norm(x, p["ln2"]), cfg, rules)
    return x + m, cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed_lookup(table, ids, rules: ShardingRules, dt):
    """Vocab-sharded embedding gather as an explicit packed indirect stream.

    Plain ``jnp.take`` on a vocab-sharded table makes the SPMD partitioner
    all-gather the whole table per device (observed: 671 MB f32 copies per
    step on rwkv6-3b).  The shard_map form keeps the gather *local* — each
    shard packs only its resident rows and a psum combines — the memory-side
    indirection move of the paper.

    The backward is explicit (custom_vjp): without it the partitioner
    all-gathers the (global_batch, S, D) cotangent to every device before
    the scatter-add (observed: a 10 GB f32 all-gather); the custom rule does
    a fully local scatter-add over (data×vocab) shards and psums only the
    table-shard gradient across 'data'.
    """
    ax = rules.axis("vocab")
    n = rules.axis_size("vocab")
    if rules.mesh is None or not isinstance(ax, str) or n == 1:
        return jnp.take(table, ids, axis=0).astype(dt)
    from jax.sharding import PartitionSpec as P

    vs = table.shape[0] // n
    mesh = rules.mesh
    batch_ax = rules.axis("batch")  # e.g. ('data',) or ('pod','data') or None
    if isinstance(batch_ax, str):
        batch_ax = (batch_ax,)

    # XLA:CPU's AllReducePromotion pass crashes on bf16 all-reduce (dry-run
    # host only); TPU does native bf16 psum.
    psum_dt = jnp.float32 if jax.default_backend() == "cpu" else dt

    def local_fwd(tbl, ids_):
        lo = jax.lax.axis_index(ax) * vs
        loc = ids_ - lo
        ok = (loc >= 0) & (loc < vs)
        x = jnp.take(tbl, jnp.clip(loc, 0, vs - 1), axis=0).astype(psum_dt)
        out = jax.lax.psum(jnp.where(ok[..., None], x, jnp.zeros((), psum_dt)), ax)
        return out.astype(dt)

    fwd_mapped = jax.shard_map(
        local_fwd, mesh=mesh, in_specs=(P(ax, None), P()), out_specs=P(),
        axis_names={ax}, check_vma=False,
    )

    manual_bwd = {ax, *(batch_ax or ())}
    ids_spec = P(batch_ax) if batch_ax else P()

    def local_bwd(ids_, g_):
        # ids_ (B_local, S); g_ (B_local, S, D) — all local, no gathers.
        lo = jax.lax.axis_index(ax) * vs
        loc = ids_ - lo
        ok = (loc >= 0) & (loc < vs)
        upd = jnp.where(ok[..., None], g_.astype(psum_dt), 0.0)
        gt = jnp.zeros((vs, g_.shape[-1]), psum_dt)
        gt = gt.at[jnp.clip(loc, 0, vs - 1).reshape(-1)].add(
            upd.reshape(-1, g_.shape[-1])
        )
        if batch_ax:
            gt = jax.lax.psum(gt, batch_ax)
        return gt

    bwd_mapped = jax.shard_map(
        local_bwd, mesh=mesh,
        in_specs=(ids_spec, ids_spec),  # trailing dims implicitly unsharded
        out_specs=P(ax, None),
        axis_names=manual_bwd, check_vma=False,
    )

    table_dtype = table.dtype  # static closure (not a vjp residual)

    @jax.custom_vjp
    def lookup(tbl, ids_):
        return fwd_mapped(tbl, ids_)

    def fwd_rule(tbl, ids_):
        return fwd_mapped(tbl, ids_), ids_

    def bwd_rule(ids_, g_):
        gt = bwd_mapped(ids_, g_)
        return gt.astype(table_dtype), None

    lookup.defvjp(fwd_rule, bwd_rule)
    return lookup(table, ids)


def embed_tokens(params, batch, cfg: ArchConfig, rules: ShardingRules):
    dt = cfg.compute_dtype
    parts = []
    if cfg.modality in ("audio", "vlm") and "frontend" in batch:
        fe = batch["frontend"].astype(dt) @ params["frontend_proj"].astype(dt)
        parts.append(fe)
    if cfg.modality != "audio":
        x = _embed_lookup(params["embed"], batch["tokens"], rules, dt)
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
        parts.append(x)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return constrain(x, rules, ("act_batch", "seq", "d_model"))


def output_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _diff_barrier(x):
    """``optimization_barrier`` with an explicit gradient rule.

    The jax pinned on this host (<0.5) has no differentiation rule for the
    barrier primitive; newer releases differentiate it as identity.  The
    custom rule barriers the cotangents too, so the backward loop keeps the
    same anti-hoisting property the forward barrier exists for.
    """
    return jax.lax.optimization_barrier(x)


def _diff_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _diff_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_diff_barrier.defvjp(_diff_barrier_fwd, _diff_barrier_bwd)


def _scan_layers(params, x, cfg, rules, body):
    flags = jnp.asarray(global_flags(cfg), jnp.float32)

    def step(carry, xs):
        lp, flag = xs
        # The barrier pins per-layer residual reads inside the backward loop:
        # without it XLA hoists the f32 upcast of the *entire* stacked
        # residual (L,B,S,D) out of the loop (observed: a 21 GB convert).
        carry = _diff_barrier(carry)
        return body(carry, lp, flag)

    if cfg.remat:
        step = jax.checkpoint(step, prevent_cse=False)
    return jax.lax.scan(step, x, (params["layers"], flags))


def train_loss(
    params, batch, cfg: ArchConfig, rules: ShardingRules
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens (B,S), targets (B,S), mask (B,S) [+ frontend]."""
    x = embed_tokens(params, batch, cfg, rules)
    s = x.shape[1]
    positions = jnp.arange(s)

    def body(carry, lp, flag):
        x, aux = carry
        x, a = _block_train(lp, x, cfg, rules, flag, positions)
        return (x, aux + a), None

    (x, aux), _ = _scan_layers(params, (x, jnp.float32(0.0)), cfg, rules, body)
    x = rms_norm(x, params["final_norm"])
    w_out = output_weight(params, cfg).astype(cfg.compute_dtype)
    tgt = batch["targets"]
    # Align targets when a frontend prefix was prepended.
    if x.shape[1] != tgt.shape[1]:
        x = x[:, x.shape[1] - tgt.shape[1]:]
    loss, cnt = chunked_softmax_xent(
        x, w_out, tgt, batch.get("mask"), n_valid=cfg.vocab,
        logit_spec=rules.spec(("act_batch", None, "vocab")),
    )
    total = loss + cfg.router_aux_coef * aux / cfg.n_layers
    return total, {"ce_loss": loss, "aux_loss": aux, "tokens": cnt}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, tp: int = 1):
    """Stacked per-layer cache pytree (leading dim = layers)."""
    q_heads, kv_heads = cfg.heads_for_tp(tp)
    if cfg.ssm == "rwkv6":
        one = rwkv_mod.init_rwkv_state(cfg, batch, rwkv_mod.rwkv_heads(cfg, tp > 1))
    elif cfg.ssm == "hymba":
        one = {
            "kv": attn_mod.init_kv_cache(cfg, q_heads, kv_heads, batch, max_len),
            "ssm": mamba_mod.init_mamba_state(cfg, batch),
        }
    else:
        one = attn_mod.init_kv_cache(cfg, q_heads, kv_heads, batch, max_len)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
    )


def cache_dims_tree(cfg: ArchConfig):
    """Logical dims for every cache leaf (layers dim prepended)."""
    if cfg.ssm == "rwkv6":
        dims = rwkv_mod.rwkv_state_dims(cfg)
    elif cfg.ssm == "hymba":
        dims = {
            "kv": attn_mod.cache_dims(cfg),
            "ssm": mamba_mod.mamba_state_dims(cfg),
        }
    else:
        dims = attn_mod.cache_dims(cfg)
    return jax.tree_util.tree_map(
        lambda d: ("layers",) + d, dims, is_leaf=lambda d: isinstance(d, tuple)
    )


def _scan_with_cache(params, x, cache, cfg, rules, block_fn):
    """Scan layers with the full cache stack as a *carry*, updated in place
    per layer (dynamic_update_index).  Carrying (vs. emitting stacked ys)
    lets XLA alias the donated cache buffer through the loop — with the ys
    form the dry-run showed a full second cache in the temp arena."""
    flags = jnp.asarray(global_flags(cfg), jnp.float32)

    def step(carry, xs):
        x, cache_all = carry
        lp, flag, i = xs
        lcache = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            cache_all,
        )
        x, new_l = block_fn(lp, x, flag, lcache)
        cache_all = jax.tree_util.tree_map(
            lambda c, nl: jax.lax.dynamic_update_index_in_dim(c, nl, i, 0),
            cache_all, new_l,
        )
        return (x, cache_all), None

    idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, cache), _ = jax.lax.scan(step, (x, cache), (params["layers"], flags, idx))
    return x, cache


def prefill(params, batch, cache, cfg: ArchConfig, rules: ShardingRules):
    """Fill the cache from a prompt; returns (last-token logits, cache)."""
    x = embed_tokens(params, batch, cfg, rules)
    x, cache = _scan_with_cache(
        params, x, cache, cfg, rules,
        lambda lp, x_, flag, lc: _block_prefill(lp, x_, cfg, rules, flag, lc),
    )
    x = rms_norm(x[:, -1:], params["final_norm"])
    w_out = output_weight(params, cfg).astype(cfg.compute_dtype)
    return x @ w_out, cache


def decode_step(params, tokens, cache, pos, cfg: ArchConfig, rules: ShardingRules):
    """One decode step: tokens (B,1) at position ``pos`` → (logits, cache)."""
    x = embed_tokens(params, {"tokens": tokens}, cfg, rules)
    x, cache = _scan_with_cache(
        params, x, cache, cfg, rules,
        lambda lp, x_, flag, lc: _block_decode(lp, x_, cfg, rules, flag, lc, pos),
    )
    x = rms_norm(x, params["final_norm"])
    w_out = output_weight(params, cfg).astype(cfg.compute_dtype)
    return (x @ w_out)[:, 0], cache


def extend_step(params, tokens, cache, pos, cfg: ArchConfig, rules: ShardingRules):
    """Process a chunk of tokens (B,C) at positions [pos, pos+C) against the
    cache (chunked prefill / vLLM-style prompt processing).  The decode
    attention path is C-generic, so this is decode_step with C>1."""
    x = embed_tokens(params, {"tokens": tokens}, cfg, rules)
    x, cache = _scan_with_cache(
        params, x, cache, cfg, rules,
        lambda lp, x_, flag, lc: _block_decode(lp, x_, cfg, rules, flag, lc, pos),
    )
    x = rms_norm(x[:, -1:], params["final_norm"])
    w_out = output_weight(params, cfg).astype(cfg.compute_dtype)
    return x @ w_out, cache


def prefill_chunked(
    params, batch, cache, cfg: ArchConfig, rules: ShardingRules, chunk: int
):
    """Prefill in fixed-size chunks: activation and attention-score memory
    scale with ``chunk`` instead of the full prompt (arctic-480b prefill_32k:
    17.1 → see EXPERIMENTS §Dry-run).  Equivalent to ``prefill`` (asserted in
    tests); MoE capacity is per-chunk, matching continuous-batching serving."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    chunks = tokens.reshape(b, n, chunk).transpose(1, 0, 2)

    def step(cache, xs):
        tok, i = xs
        logits, cache = extend_step(params, tok, cache, i * chunk, cfg, rules)
        return cache, logits

    cache, logits = jax.lax.scan(
        step, cache, (chunks, jnp.arange(n, dtype=jnp.int32))
    )
    return logits[-1], cache
