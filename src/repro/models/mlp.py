"""Dense MLP and Mixture-of-Experts layers.

The MoE layer is the framework's flagship packed-stream consumer: token
dispatch is an *indirect write* into expert-contiguous buffers and combine is
an *indirect read* back (repro.kernels.ops.moe_dispatch/combine).  Training
uses the differentiable ref path (XLA scatter/gather — same stream
semantics); serving can route through the Pallas converters.

Sharding: experts over the 'model' axis (EP), dispatch buffers' capacity dim
over 'data', so the dispatch lowers to the canonical MoE all-to-all.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.parallel.sharding import ShardingRules, constrain
from .common import ACTIVATIONS, Param


# ---------------------------------------------------------------------------
# Dense (gated) MLP
# ---------------------------------------------------------------------------


def mlp_defs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, Param]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    defs = {
        "w_up": Param((d, f), ("fsdp_mlp", "d_ff")),
        "w_down": Param((f, d), ("d_ff", "fsdp_mlp")),
    }
    if cfg.glu:
        defs["w_gate"] = Param((d, f), ("fsdp_mlp", "d_ff"))
    return defs


def _w(leaf, dt):
    """Weight read: plain array, or w8a16 {'q': int8, 'scale': per-channel}.

    Int8 weights are the serving-side narrow-element packing (§III-E):
    half the HBM stream per matmul and half the resident bytes; dequant
    happens at VMEM/register level.
    """
    if isinstance(leaf, dict) and "q" in leaf:
        return leaf["q"].astype(dt) * leaf["scale"].astype(dt)
    return leaf.astype(dt)


def mlp_fwd(p, x, cfg: ArchConfig, rules: ShardingRules) -> jax.Array:
    dt = cfg.compute_dtype
    act = ACTIVATIONS[cfg.activation]
    up = x @ _w(p["w_up"], dt)
    up = constrain(up, rules, ("act_batch", "seq", "d_ff"))
    h = act(up) * (x @ _w(p["w_gate"], dt)) if cfg.glu else act(up)
    out = h @ _w(p["w_down"], dt)
    return constrain(out, rules, ("act_batch", "seq", "d_model"))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_defs(cfg: ArchConfig) -> Dict[str, Param]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    defs = {
        "router": Param((d, e), ("d_model", None), scale=0.02),
        "w_up": Param((e, d, f), ("experts", "fsdp_mlp", None)),
        "w_down": Param((e, f, d), ("experts", None, "fsdp_mlp")),
    }
    if cfg.glu:
        defs["w_gate"] = Param((e, d, f), ("experts", "fsdp_mlp", None))
    if cfg.dense_residual:
        defs["dense"] = mlp_defs(cfg)
    return defs


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    cap = math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8 (pack granularity)


def _expert_ffn(p, buf, cfg: ArchConfig):
    dt = cfg.compute_dtype
    act = ACTIVATIONS[cfg.activation]
    up = jnp.einsum("ecd,edf->ecf", buf, _w(p["w_up"], dt))
    if cfg.glu:
        h = act(up) * jnp.einsum("ecd,edf->ecf", buf, _w(p["w_gate"], dt))
    else:
        h = act(up)
    return jnp.einsum("ecf,efd->ecd", h, _w(p["w_down"], dt))


def _router(p, flat, cfg: ArchConfig):
    logits = (flat @ p["router"].astype(cfg.compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)               # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * Σ_e fraction_e * mean_prob_e.
    frac = jnp.mean(
        jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32).sum(1), axis=0
    ) / cfg.top_k
    aux = cfg.n_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    return gate, idx, aux


def moe_fwd(
    p, x, cfg: ArchConfig, rules: ShardingRules, impl: str = "ref"
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss).

    Two lowerings:
    * **EP shard_map path** (mesh present, experts on 'model'): activations
      are replicated across the model axis between blocks, so each device
      packs its local tokens for *its own* expert shard entirely locally —
      near-memory packing, no token movement — and the combine is one
      bf16 (T,D) psum.  The SPMD-partitioned scatter path instead emitted
      full dispatch-buffer all-reduces (observed 1.2 TB/device/step on
      olmoe train — EXPERIMENTS.md §Perf).
    * fallback (no mesh / unsharded experts): the portable scatter/gather
      path via repro.kernels.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    flat = x.reshape(t, d)
    ep = rules.axis("experts")
    n_ep = rules.axis_size("experts")

    if rules.mesh is not None and isinstance(ep, str) and n_ep > 1:
        out, aux = _ep_moe_fwd(p, flat, cfg, rules, ep, n_ep)
        out = out.reshape(b, s, d)
    else:
        gate, idx, aux = _router(p, flat, cfg)
        cap = moe_capacity(cfg, t)
        buf, src, keep = kops.moe_dispatch(flat, idx, e, cap, impl=impl)
        buf = constrain(buf, rules, ("experts", "capacity", None))
        out_buf = _expert_ffn(p, buf, cfg)
        out_buf = constrain(out_buf, rules, ("experts", "capacity", None))
        out = kops.moe_combine(out_buf, src, gate * keep, t, impl=impl)
        out = out.reshape(b, s, d)

    if cfg.dense_residual:
        out = out + mlp_fwd(p["dense"], x, cfg, rules)
    return constrain(out, rules, ("act_batch", "seq", "d_model")), aux


def _ep_moe_fwd(p, flat, cfg: ArchConfig, rules: ShardingRules, ep: str, n_ep: int):
    """Expert-parallel MoE via shard_map (manual over the experts axis).

    Per model shard: route (identical math on every shard), select the
    assignments that hit the shard's E/n experts, pack them locally
    (capacity per expert per data-shard), run the local expert FFN, combine
    locally gate-weighted, and psum partial outputs across shards.
    Everything but the final (T_local, D) psum is device-local.
    """
    from jax.sharding import PartitionSpec as P

    e_loc = cfg.n_experts // n_ep
    t = flat.shape[0]
    # Capacity per (expert, data shard): same expected load as the global
    # formula over the data-sharded token count.
    t_shard = max(1, t // max(1, rules.axis_size("batch")))
    cap = moe_capacity(cfg, t_shard)
    dt = cfg.compute_dtype
    psum_dt = jnp.float32 if jax.default_backend() == "cpu" else dt

    def local(router_w, w_up, w_gate, w_down, tokens):
        # Boundary values arrive in psum_dt: replicated-input cotangents are
        # psummed over the manual axis in this dtype (XLA:CPU cannot lower
        # bf16 all-reduce; TPU runs this in bf16).
        tokens = tokens.astype(dt)
        m = jax.lax.axis_index(ep)
        gate, idx, aux = _router({"router": router_w}, tokens, cfg)
        local_idx = idx - m * e_loc
        ok = (local_idx >= 0) & (local_idx < e_loc)
        # non-local assignments route to the overflow expert e_loc (dropped)
        masked = jnp.where(ok, local_idx, e_loc)
        buf, src, keep = kref.moe_dispatch(tokens, masked, e_loc + 1, cap)
        out_buf = _expert_ffn(
            {"w_up": w_up, "w_gate": w_gate, "w_down": w_down},
            buf[:e_loc], cfg,
        )
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((1,) + out_buf.shape[1:], out_buf.dtype)]
        )
        partial = kref.moe_combine(
            out_buf, src, (gate * keep * ok).astype(jnp.float32),
            tokens.shape[0],
        )
        out = jax.lax.psum(partial.astype(psum_dt), ep).astype(dt)
        # aux is identical on every shard (router math is replicated)
        return out, aux

    w_gate = p.get("w_gate")

    def wspec(w):  # dict for w8a16 {'q','scale'}, bare spec otherwise
        return jax.tree_util.tree_map(lambda _: P(ep, None, None), w)

    in_specs = (
        P(),                    # router: replicated over model
        wspec(p["w_up"]),       # expert weights: experts on the manual axis
        wspec(w_gate) if w_gate is not None else P(),
        wspec(p["w_down"]),
        P(),                    # tokens: replicated over model (auto on data)
    )
    mapped = jax.shard_map(
        local, mesh=rules.mesh,
        in_specs=in_specs,
        out_specs=(P(), P()),
        axis_names={ep}, check_vma=False,
    )
    return mapped(
        p["router"].astype(psum_dt), p["w_up"], w_gate, p["w_down"],
        flat.astype(psum_dt),
    )
