"""Model zoo: composable blocks + top-level LM assembly for all families."""
from . import attention, common, lm, mamba, mlp, rwkv6
