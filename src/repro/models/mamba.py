"""Selective SSM (S6/Mamba) head and the Hymba parallel attn+SSM block.

The selective scan  h_t = exp(Δ_t ⊙ A) h_{t-1} + Δ_t B_t x_t,  y_t = C_t·h_t
runs through the same chunked decayed-cumsum helper as RWKV: exact and
O(chunk·d_inner·N) memory.  All per-token projections (Δ, B, C) are computed
*inside* the chunk scan so the (T, d_inner, N) tensors never materialize —
required for the 500k-token shapes.

Hymba block: attention heads and a Mamba head run *in parallel* on the same
normed input; each path is output-normed then averaged (arXiv:2411.13676).
Meta-tokens from the paper are out of scope (noted in DESIGN.md).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ShardingRules, constrain
from .common import Param, decayed_cumsum, rms_norm
from .attention import attention_defs, attention_fwd, attention_decode, attention_prefill


def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = 2 * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return d_inner, dt_rank, cfg.ssm_state


def mamba_defs(cfg: ArchConfig) -> Dict[str, Param]:
    d = cfg.d_model
    di, r, n = mamba_dims(cfg)
    k = cfg.ssm_conv
    return {
        "w_in": Param((d, 2 * di), ("fsdp", "d_ff")),
        "conv_w": Param((k, di), (None, "d_ff"), scale=0.1),
        "conv_b": Param((di,), ("d_ff",), init="zeros"),
        "w_x": Param((di, r + 2 * n), ("d_ff", None)),
        "w_dt": Param((r, di), (None, "d_ff")),
        "b_dt": Param((di,), ("d_ff",), init="zeros"),
        "a_log": Param((di, n), ("d_ff", "ssm_state"), init="ones"),
        "d_skip": Param((di,), ("d_ff",), init="ones"),
        "w_out": Param((di, d), ("d_ff", "fsdp")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None):
    """Depthwise causal conv over time. x (B,T,di); w (K,di); tail (B,K-1,di)."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros_like(x[:, : k - 1])
    else:
        pad = tail
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k))
    new_tail = xp[:, -(k - 1):] if k > 1 else None
    return out + b, new_tail


def mamba_fwd(
    p, x, cfg: ArchConfig, rules: ShardingRules,
    state: Optional[Dict[str, jax.Array]] = None,
    chunk: int = 16,
):
    """x (B,T,D) → (y (B,T,D), new_state). state carries {'h', 'conv'}."""
    dt_ = cfg.compute_dtype
    b, t, d = x.shape
    di, r, n = mamba_dims(cfg)
    xz = x @ p["w_in"].astype(dt_)
    xm, z = jnp.split(xz, 2, axis=-1)
    xm = constrain(xm, rules, ("act_batch", "seq", "d_ff"))
    tail = None if state is None else state["conv"]
    xm, new_tail = _causal_conv(xm, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), tail)
    xm = jax.nn.silu(xm)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))         # (di, N)
    h0 = (
        jnp.zeros((b, di, n), jnp.float32) if state is None else state["h"]
    )
    chunk = min(chunk, t)
    assert t % chunk == 0
    n_chunks = t // chunk
    xc = xm.reshape(b, n_chunks, chunk, di).transpose(1, 2, 0, 3)  # (n,C,B,di)

    wx = p["w_x"].astype(dt_)
    wdt = p["w_dt"].astype(dt_)
    bdt = p["b_dt"].astype(jnp.float32)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(h, xcc):
        proj = xcc @ wx                                    # (C,B,r+2N)
        dt_r, bm, cm = jnp.split(proj, [r, r + n], axis=-1)
        delta = jax.nn.softplus((dt_r @ wdt).astype(jnp.float32) + bdt)  # (C,B,di)
        da = jnp.exp(delta[..., None] * a)                 # (C,B,di,N)
        db = (delta * xcc.astype(jnp.float32))[..., None] * bm.astype(jnp.float32)[:, :, None, :]
        hs, h_new = decayed_cumsum(da, db, h, chunk=da.shape[0])
        y = jnp.einsum("cbdn,cbn->cbd", hs, cm.astype(jnp.float32))
        return h_new, y

    h_final, ys = jax.lax.scan(step, h0, xc)
    y = ys.transpose(2, 0, 1, 3).reshape(b, t, di).astype(dt_)
    y = y + p["d_skip"].astype(dt_) * xm
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(dt_)
    new_state = {"h": h_final, "conv": new_tail}
    return constrain(out, rules, ("act_batch", "seq", "d_model")), new_state


def init_mamba_state(cfg: ArchConfig, batch: int) -> Dict[str, jax.Array]:
    di, _, n = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, di, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), cfg.compute_dtype),
    }


def mamba_state_dims(cfg: ArchConfig):
    return {
        "h": ("cache_batch", "d_ff", "ssm_state"),
        "conv": ("cache_batch", None, "d_ff"),
    }


# ---------------------------------------------------------------------------
# Hymba parallel hybrid block
# ---------------------------------------------------------------------------


def hymba_defs(cfg: ArchConfig, q_heads: int, kv_heads: int) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "attn": attention_defs(cfg, q_heads, kv_heads),
        "mamba": mamba_defs(cfg),
        "norm_attn": Param((d,), ("d_model",), init="zeros"),
        "norm_mamba": Param((d,), ("d_model",), init="zeros"),
    }


def hymba_mix(p, attn_out, mamba_out, cfg: ArchConfig):
    """Per-path output norm, then average (Hymba §3.1)."""
    a = rms_norm(attn_out, p["norm_attn"])
    m = rms_norm(mamba_out, p["norm_mamba"])
    return 0.5 * (a + m)


def hymba_block_fwd(
    p, x, cfg: ArchConfig, rules: ShardingRules, is_global, positions
):
    """Train/no-cache path."""
    attn_out = attention_fwd(p["attn"], x, cfg, rules, is_global, positions)
    mamba_out, _ = mamba_fwd(p["mamba"], x, cfg, rules)
    return hymba_mix(p, attn_out, mamba_out, cfg)


def hymba_block_prefill(p, x, cfg, rules, is_global, cache):
    attn_out, kv = attention_prefill(p["attn"], x, cfg, rules, is_global, cache["kv"])
    mamba_out, ssm = mamba_fwd(p["mamba"], x, cfg, rules)
    return hymba_mix(p, attn_out, mamba_out, cfg), {"kv": kv, "ssm": ssm}


def hymba_block_decode(p, x, cfg, rules, is_global, cache, pos):
    attn_out, kv = attention_decode(p["attn"], x, cfg, rules, is_global, cache["kv"], pos)
    mamba_out, ssm = mamba_fwd(p["mamba"], x, cfg, rules, state=cache["ssm"])
    return hymba_mix(p, attn_out, mamba_out, cfg), {"kv": kv, "ssm": ssm}
