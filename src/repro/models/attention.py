"""GQA attention layer: QKV(+bias) projections, RoPE, sliding windows, caches.

Three execution paths share one parameter set:

* train/prefill — chunked flash attention (differentiable, O(chunk) memory);
* decode        — one-token query against a sequence-sharded KV cache
                  (flash-decoding SP: softmax reductions over the sharded seq
                  dim lower to psums);
* decode (int8) — quantized KV cache (packed narrow elements, §III-E analogue).

gemma3-style mixed local/global stacks run inside one ``lax.scan``: the
per-layer ``is_global`` flag is a *traced* scalar steering the mask and RoPE
theta, so both layer kinds compile once.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ref as kref
from repro.parallel.sharding import ShardingRules, constrain
from .common import Param, apply_rope, chunked_mha


def attention_defs(cfg: ArchConfig, q_heads: int, kv_heads: int) -> Dict[str, Param]:
    d, hd = cfg.d_model, cfg.hd
    defs = {
        "wq": Param((d, q_heads, hd), ("fsdp", "heads", "head_dim")),
        "wk": Param((d, kv_heads, hd), ("fsdp", "kv_heads_w", "head_dim")),
        "wv": Param((d, kv_heads, hd), ("fsdp", "kv_heads_w", "head_dim")),
        "wo": Param((q_heads, hd, d), ("heads", "head_dim", "fsdp")),
    }
    if cfg.qkv_bias:
        defs["bq"] = Param((q_heads, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = Param((kv_heads, hd), ("kv_heads_w", "head_dim"), init="zeros")
        defs["bv"] = Param((kv_heads, hd), ("kv_heads_w", "head_dim"), init="zeros")
    return defs


def _qkv(p, x, cfg: ArchConfig, rules: ShardingRules):
    dt = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = constrain(q, rules, ("act_batch", "seq", "heads", "head_dim"))
    k = constrain(k, rules, ("act_batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, rules, ("act_batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _rope_dual(x, positions, cfg: ArchConfig, is_global):
    """RoPE with traced local/global theta select (gemma3: 10k local, 1M global)."""
    if cfg.global_interval is None:
        return apply_rope(x, positions, cfg.rope_theta)
    local = apply_rope(x, positions, 1e4)
    glob = apply_rope(x, positions, cfg.rope_theta)
    flag = jnp.asarray(is_global, x.dtype)
    return glob * flag + local * (1.0 - flag)


def _masked_attention(
    q, k, v, cfg: ArchConfig, is_global, q_offset, kv_len=None, kv_chunk=1024
):
    """Attention with a traced window on/off switch (single compiled body,
    chunked online-softmax — never materializes (S, Skv) scores)."""
    if cfg.window is None:
        return chunked_mha(
            q, k, v, causal=cfg.causal, window=None,
            q_offset=q_offset, kv_chunk=kv_chunk,
        )
    # Mixed stack (gemma3 5:1, hymba's 3 global layers): the window applies
    # only when the traced flag says local.
    return chunked_mha(
        q, k, v, causal=cfg.causal, window=cfg.window,
        q_offset=q_offset, kv_chunk=kv_chunk,
        window_flag=jnp.asarray(is_global, bool),
    )


def attention_fwd(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ArchConfig,
    rules: ShardingRules,
    is_global,
    positions: jax.Array,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Full-sequence path (train / prefill without cache)."""
    q, k, v = _qkv(p, x, cfg, rules)
    q = _rope_dual(q, positions, cfg, is_global)
    k = _rope_dual(k, positions, cfg, is_global)
    if cfg.global_interval is None:
        out = _masked_attention(q, k, v, cfg, is_global, q_offset=0, kv_chunk=kv_chunk)
    else:
        out = _masked_attention(q, k, v, cfg, is_global, q_offset=0)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.compute_dtype))
    return constrain(out, rules, ("act_batch", "seq", "d_model"))


# ---------------------------------------------------------------------------
# KV cache (contiguous, sequence-sharded) — prefill fill + decode step
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: ArchConfig, q_heads: int, kv_heads: int, batch: int, max_len: int
):
    """Per-layer cache arrays (stacked over layers by the caller)."""
    hd = cfg.hd
    if cfg.cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, max_len, kv_heads, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, kv_heads, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, kv_heads), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, kv_heads), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_len, kv_heads, hd), cfg.compute_dtype),
        "v": jnp.zeros((batch, max_len, kv_heads, hd), cfg.compute_dtype),
    }


def cache_dims(cfg: ArchConfig):
    """Logical dims of each cache leaf (for sharding specs)."""
    dims4 = ("cache_batch", "cache_seq", "kv_heads", "head_dim")
    dims3 = ("cache_batch", "cache_seq", "kv_heads")
    if cfg.cache_dtype == "int8":
        return {"k": dims4, "v": dims4, "k_scale": dims3, "v_scale": dims3}
    return {"k": dims4, "v": dims4}


def _store_kv(cache, k_new, v_new, pos, cfg: ArchConfig, rules: ShardingRules):
    """Write S_new tokens at ``pos`` into the (sharded) cache."""
    dims = cache_dims(cfg)
    if cfg.cache_dtype == "int8":
        kq, ks = kref.int8_quantize(k_new, axis=-1)
        vq, vs = kref.int8_quantize(v_new, axis=-1)
        upd = {
            "k": kq, "v": vq, "k_scale": ks[..., 0], "v_scale": vs[..., 0],
        }
    else:
        upd = {"k": k_new.astype(cache["k"].dtype), "v": v_new.astype(cache["v"].dtype)}
    out = {}
    for name, val in upd.items():
        start = (0, pos) + (0,) * (val.ndim - 2)
        new = jax.lax.dynamic_update_slice(cache[name], val, start)
        out[name] = constrain(new, rules, dims[name])
    return out


def _read_kv(cache, cfg: ArchConfig):
    if cfg.cache_dtype == "int8":
        k = cache["k"].astype(jnp.float32) * cache["k_scale"][..., None]
        v = cache["v"].astype(jnp.float32) * cache["v_scale"][..., None]
        return k.astype(cfg.compute_dtype), v.astype(cfg.compute_dtype)
    return cache["k"], cache["v"]


def attention_prefill(
    p, x, cfg: ArchConfig, rules: ShardingRules, is_global, cache, kv_chunk=1024
):
    """Prefill: full-seq attention + fill cache positions [0, S)."""
    positions = jnp.arange(x.shape[1])
    q, k, v = _qkv(p, x, cfg, rules)
    q = _rope_dual(q, positions, cfg, is_global)
    k_r = _rope_dual(k, positions, cfg, is_global)
    if cfg.global_interval is None:
        out = _masked_attention(q, k_r, v, cfg, is_global, 0, kv_chunk=kv_chunk)
    else:
        out = _masked_attention(q, k_r, v, cfg, is_global, 0)
    cache = _store_kv(cache, k_r, v, 0, cfg, rules)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.compute_dtype))
    return constrain(out, rules, ("act_batch", "seq", "d_model")), cache


def attention_decode(
    p, x, cfg: ArchConfig, rules: ShardingRules, is_global, cache, pos
):
    """Decode/extend against the sequence-sharded cache.

    x (B,C,D) — C=1 for decode, C=chunk for chunked prefill (extend).  The
    (B,H,C,S) score reduction over the 'cache_seq'-sharded axis is the
    flash-decoding collective; per-chunk memory is C·S per head group.
    """
    c = x.shape[1]
    positions = pos + jnp.arange(c)
    q, k_new, v_new = _qkv(p, x, cfg, rules)
    q = _rope_dual(q, positions, cfg, is_global)
    k_new = _rope_dual(k_new, positions, cfg, is_global)
    cache = _store_kv(cache, k_new, v_new, pos, cfg, rules)
    k, v = _read_kv(cache, cfg)

    b, _, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, c, kvh, rep, hd).astype(jnp.float32) * scale
    sc = jnp.einsum("bcgrd,bsgd->bgrcs", qg, k.astype(jnp.float32))
    kpos = jnp.arange(k.shape[1])[None, :]                  # (1, S)
    qpos = (pos + jnp.arange(c))[:, None]                   # (C, 1)
    mask = kpos <= qpos
    if cfg.window is not None:
        # window off on traced-global layers (gemma3 1-in-6, hymba's 3)
        win = (qpos - kpos) < cfg.window
        win = win | jnp.asarray(is_global, bool)
        mask = mask & win
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrcs,bsgd->bcgrd", w, v.astype(jnp.float32))
    out = out.reshape(b, c, h, hd).astype(cfg.compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.compute_dtype))
    return constrain(out, rules, ("act_batch", "seq", "d_model")), cache
