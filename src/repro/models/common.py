"""Shared model machinery: params-with-named-dims, norms, RoPE, chunked ops.

Parameters are declared once as :class:`Param` (shape + *logical dim names* +
init); the same declaration yields both the initialized arrays and the
``PartitionSpec`` tree (see :mod:`repro.parallel.sharding`), so sharding can
never drift from the parameter structure.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Param:
    """A parameter declaration: shape, logical dim names, initializer."""

    shape: Tuple[int, ...]
    dims: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override (default: fan-in)

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)


def init_params(defs: Pytree, key: jax.Array, dtype=jnp.float32) -> Pytree:
    """Materialize a Param-def tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, Param)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            if p.scale is not None:
                std = p.scale
            elif p.init == "embed":
                # 1/sqrt(d_model): keeps tied-head logits O(1).
                std = 1.0 / math.sqrt(p.shape[-1])
            else:
                fan_in = p.shape[0] if len(p.shape) == 1 else int(np.prod(p.shape[:-1]))
                std = 1.0 / math.sqrt(max(fan_in, 1))
            out.append(jax.random.normal(k, p.shape, dtype) * std)
    return jax.tree_util.tree_unflatten(treedef, out)


def map_params(fn: Callable[[Param], Any], defs: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        fn, defs, is_leaf=lambda x: isinstance(x, Param)
    )


def stack_layer_defs(defs: Pytree, n_layers: int) -> Pytree:
    """Prepend a 'layers' dim to every Param (for lax.scan-stacked layers)."""
    return map_params(
        lambda p: Param((n_layers,) + p.shape, ("layers",) + p.dims, p.init, p.scale),
        defs,
    )


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    # Square in x.dtype, accumulate the sum in f32 (reduce-with-f32-accum
    # reads x natively).  Materializing x.astype(f32) instead makes XLA stage
    # a full f32 copy of the (L,B,S,D) remat residual stack ahead of the
    # backward loop (+7.7 GB/device on arctic-480b).  bf16 squares cost ~3
    # mantissa bits on the variance — standard practice (bf16 layernorms).
    var = (
        jnp.sum(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
        / x.shape[-1]
    )
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * (1.0 + gamma.astype(x.dtype))


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps=1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd); positions (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                            # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention in pure jnp — differentiable, O(chunk) mem.
# ---------------------------------------------------------------------------


def chunked_mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_chunk: int = 1024,
    scale: Optional[float] = None,
    window_flag: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax attention scanned over KV chunks (GQA-aware, no repeat).

    q (B,S,H,hd); k,v (B,Skv,KVH,hd).  Memory high-water: one (B,S,chunk)
    score block per KV head group — the jnp analogue of the flash kernel, and
    the differentiable training path.

    ``window_flag``: traced bool disabling the window when True (gemma3-style
    mixed local/global stacks compile one body for both layer kinds).
    """
    b, s, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kv_chunk = min(kv_chunk, skv)
    assert skv % kv_chunk == 0
    n_chunks = skv // kv_chunk

    qg = q.reshape(b, s, kvh, rep, hd).astype(jnp.float32) * scale
    kc = k.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(s)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, c = xs
        kb = kb.astype(jnp.float32)
        # scores: (B, S, KVH, rep, chunk)
        sc = jnp.einsum("bsgrd,bcgd->bsgrc", qg, kb)
        kpos = c * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((s, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= kpos[None, :]
        if window is not None:
            win = q_pos[:, None] - kpos[None, :] < window
            if window_flag is not None:
                win = win | jnp.asarray(window_flag, bool)
            mask &= win
        sc = jnp.where(mask[None, :, None, None, :], sc, -1e30)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bsgrc,bcgd->bsgrd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, kvh, rep), -1e30, jnp.float32)
    l0 = jnp.zeros((b, s, kvh, rep), jnp.float32)
    a0 = jnp.zeros((b, s, kvh, rep, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks))
    )
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(b, s, h, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked decayed linear recurrence: h_t = a_t * h_{t-1} + b_t  (elementwise)
# Shared by RWKV6 (Finch) and the Mamba/S6 heads.
# ---------------------------------------------------------------------------


def decayed_cumsum(
    a: jax.Array, b: jax.Array, h0: jax.Array, chunk: int = 64
) -> Tuple[jax.Array, jax.Array]:
    """Returns (h for every step (T, ...), final h).  a,b: (T, ...); h0 (...)."""
    t = a.shape[0]
    chunk = min(chunk, t)
    assert t % chunk == 0
    n = t // chunk
    a_c = a.reshape((n, chunk) + a.shape[1:])
    b_c = b.reshape((n, chunk) + b.shape[1:])

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by

    def step(h, ab):
        ac, bc = ab
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=0)
        hs = aa * h + bb
        return hs[-1], hs

    h_last, hs = jax.lax.scan(step, h0, (a_c, b_c))
    return hs.reshape((t,) + a.shape[1:]), h_last


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (vocab-sharded logits, seq-chunked memory)
# ---------------------------------------------------------------------------


def chunked_softmax_xent(
    x: jax.Array,
    w_out: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    seq_chunk: int = 512,
    n_valid: Optional[int] = None,
    logit_spec=None,
) -> Tuple[jax.Array, jax.Array]:
    """Mean CE of ``softmax(x @ w_out)`` vs labels, scanning over seq chunks.

    x (B,S,D); w_out (D,V); labels (B,S).  Never materializes (B,S,V) — only
    (B,chunk,V) — which is what keeps 32k-seq training steps in memory.
    ``n_valid``: real vocab size when V is TP-padded (padded classes masked).
    Returns (loss, total_weight).
    """
    b, s, d = x.shape
    v = w_out.shape[1]
    pad_mask = None
    if n_valid is not None and n_valid < v:
        pad_mask = (jnp.arange(v) < n_valid)[None, None, :]
    seq_chunk = min(seq_chunk, s)
    assert s % seq_chunk == 0
    n = s // seq_chunk
    xs = x.reshape(b, n, seq_chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, seq_chunk).transpose(1, 0, 2)
    ms = (
        mask.reshape(b, n, seq_chunk).transpose(1, 0, 2)
        if mask is not None
        else jnp.ones((n, b, seq_chunk), x.dtype)
    )

    def step(carry, xs_):
        tot, cnt = carry
        xc, lc, mc = xs_
        logits = (xc @ w_out).astype(jnp.float32)
        if logit_spec is not None:
            try:
                logits = jax.lax.with_sharding_constraint(logits, logit_spec)
            except (ValueError, RuntimeError):
                pass
        if pad_mask is not None:
            logits = jnp.where(pad_mask, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + jnp.sum(nll), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ls, ms)
    )
    return tot / jnp.maximum(cnt, 1.0), cnt
