"""RWKV6 "Finch" block: data-dependent-decay time mix + channel mix.

The WKV recurrence  S_t = diag(w_t) S_{t-1} + k_t vᵀ_t,
                    y_t = r_t·(S_{t-1} + diag(u) k_t vᵀ_t)
is evaluated with the chunked decayed-cumsum helper: exact, differentiable,
O(chunk·H·hd²) live memory — the recurrent state never materializes for the
whole sequence.  Decode is a single state update (attention-free: this arch
is the long_500k-capable pure-SSM assignee; packed streams only touch its
embedding/LM-head gathers — see DESIGN.md §Arch-applicability).

TP: time-mix projections are head-shaped (d → H×64) and shard over 'model'
like attention heads (padded 40→48 under TP-16, recorded in the config).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import ShardingRules, constrain
from .common import Param, decayed_cumsum, rms_norm

LORA_R = 32
HEAD_DIM = 64
MIX_NAMES = ("r", "w", "k", "v", "g")


def rwkv_heads(cfg: ArchConfig, padded: bool = False) -> int:
    if padded and cfg.tp_pad_heads:
        return cfg.tp_pad_heads
    return cfg.d_model // HEAD_DIM


def rwkv_defs(cfg: ArchConfig, heads: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    h = heads or rwkv_heads(cfg)
    tm = {
        "mu_x": Param((d,), ("d_model",), init="zeros"),
        "mu": Param((5, d), (None, "d_model"), init="zeros"),
        "lora_a": Param((5, d, LORA_R), (None, "d_model", None), scale=0.01),
        "lora_b": Param((5, LORA_R, d), (None, None, "d_model"), scale=0.01),
        "w_base": Param((h, HEAD_DIM), ("heads", "head_dim"), init="zeros"),
        "wa": Param((d, LORA_R * 2), ("d_model", None), scale=0.01),
        "wb": Param((LORA_R * 2, h, HEAD_DIM), (None, "heads", "head_dim"), scale=0.01),
        "u": Param((h, HEAD_DIM), ("heads", "head_dim"), init="zeros"),
        "wr": Param((d, h, HEAD_DIM), ("fsdp", "heads", "head_dim")),
        "wk": Param((d, h, HEAD_DIM), ("fsdp", "heads", "head_dim")),
        "wv": Param((d, h, HEAD_DIM), ("fsdp", "heads", "head_dim")),
        "wg": Param((d, h, HEAD_DIM), ("fsdp", "heads", "head_dim")),
        "wo": Param((h, HEAD_DIM, d), ("heads", "head_dim", "fsdp")),
        "ln_g": Param((h, HEAD_DIM), ("heads", "head_dim"), init="zeros"),
    }
    cm = {
        "mu_k": Param((d,), ("d_model",), init="zeros"),
        "mu_r": Param((d,), ("d_model",), init="zeros"),
        "wk": Param((d, f), ("fsdp", "d_ff")),
        "wv": Param((f, d), ("d_ff", "fsdp")),
        # receptance gate output dim shards over the model axis ('heads'):
        # replicated it costs d² per layer in params+grads+moments.
        "wr": Param((d, d), ("fsdp", "heads")),
    }
    return {"tm": tm, "cm": cm}


def _shift(x: jax.Array, x_last: Optional[jax.Array]) -> jax.Array:
    """Token shift: previous token's activation (zeros / carried at t=0)."""
    prev = jnp.zeros_like(x[:, :1]) if x_last is None else x_last[:, None]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv6(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
    u: jax.Array, s0: jax.Array, chunk: int = 32,
) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,T,H,hd); u: (H,hd); s0: (B,H,hd,hd) → (y, s_final).

    The chunk step is rematerialized (jax.checkpoint): backward keeps only
    the per-chunk state carry (B·H·hd² f32) and recomputes the chunk-local
    (C,B,H,hd,hd) tensors — without this, training a 4k sequence would
    retain ~T/C × C·B·H·hd² bytes of scan residuals (observed 62 GB/device
    on the rwkv6-3b dry-run; 3.4 GB after — EXPERIMENTS.md §Perf).
    """
    b, t, h, hd = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    n = t // chunk

    def to_chunks(x):
        return x.reshape(b, n, chunk, h, hd).transpose(1, 2, 0, 3, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))  # (n, C, B, H, hd)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(s, xs):
        rcc, kcc, vcc, wcc = (x.astype(jnp.float32) for x in xs)
        a = jnp.broadcast_to(wcc[..., None], wcc.shape + (hd,))
        bb = kcc[..., None] * vcc[..., None, :]
        hs, s_new = decayed_cumsum(a, bb, s, chunk=a.shape[0])
        s_prev = jnp.concatenate([s[None], hs[:-1]], axis=0)
        y = jnp.einsum("cbhk,cbhkv->cbhv", rcc, s_prev)
        bonus = jnp.einsum("cbhk,hk,cbhk->cbh", rcc, u.astype(jnp.float32), kcc)
        y = y + bonus[..., None] * vcc
        return s_new, y

    s_final, ys = jax.lax.scan(step, s0.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.transpose(2, 0, 1, 3, 4).reshape(b, t, h, hd)
    return y.astype(r.dtype), s_final


def _ddlerp(p, x, sx):
    """Data-dependent lerp producing the five mixed inputs (r,w,k,v,g).

    Computed per-name (not as one stacked (5,B,T,D) einsum): the stacked form
    made the backward materialize 5×(B·T,D) f32 cotangents at once (~15 GB on
    the rwkv6-3b train_4k dry-run).
    """
    xxx = x + sx * p["mu_x"]
    out = {}
    for i, name in enumerate(MIX_NAMES):
        lora = jnp.tanh(xxx @ p["lora_a"][i]) @ p["lora_b"][i]
        out[name] = x + sx * (p["mu"][i] + lora)
    return out


def time_mix(
    p, x, cfg: ArchConfig, rules: ShardingRules,
    state: Optional[Dict[str, jax.Array]] = None,
):
    """state: {'s': (B,H,hd,hd), 'x_tm': (B,D)} for decode; None for train."""
    dt = cfg.compute_dtype
    b, t, d = x.shape
    h = p["u"].shape[0]
    x_last = None if state is None else state["x_tm"]
    sx = _shift(x, x_last) - x
    pf = {k_: v_.astype(dt) for k_, v_ in p.items()}
    mixed = _ddlerp(pf, x, sx)

    r = jnp.einsum("bsd,dhk->bshk", mixed["r"], pf["wr"])
    k = jnp.einsum("bsd,dhk->bshk", mixed["k"], pf["wk"])
    v = jnp.einsum("bsd,dhk->bshk", mixed["v"], pf["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", mixed["g"], pf["wg"]))
    for name, arr in (("r", r), ("k", k), ("v", v)):
        constrain(arr, rules, ("act_batch", "seq", "heads", "head_dim"))
    w_log = p["w_base"].astype(jnp.float32) + jnp.einsum(
        "bsd,dr,rhk->bshk",
        mixed["w"].astype(jnp.float32),
        jnp.tanh(p["wa"].astype(jnp.float32)),
        p["wb"].astype(jnp.float32),
    )
    w = jnp.exp(-jnp.exp(w_log))

    s0 = (
        jnp.zeros((b, h, HEAD_DIM, HEAD_DIM), jnp.float32)
        if state is None
        else state["s"]
    )
    y, s_new = wkv6(r, k, v, w.astype(r.dtype), p["u"], s0)

    # per-head group norm
    mu = jnp.mean(y.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.var(y.astype(jnp.float32), axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).astype(dt)
    y = y * (1.0 + pf["ln_g"]) * g
    out = jnp.einsum("bshk,hkd->bsd", y, pf["wo"])
    new_state = {"s": s_new, "x_tm": x[:, -1]}
    return constrain(out, rules, ("act_batch", "seq", "d_model")), new_state


def channel_mix(
    p, x, cfg: ArchConfig, rules: ShardingRules,
    state: Optional[Dict[str, jax.Array]] = None,
):
    dt = cfg.compute_dtype
    x_last = None if state is None else state["x_cm"]
    sx = _shift(x, x_last) - x
    xk = x + sx * p["mu_k"].astype(dt)
    xr = x + sx * p["mu_r"].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    k = constrain(k, rules, ("act_batch", "seq", "d_ff"))
    v = k @ p["wv"].astype(dt)
    r = jax.nn.sigmoid(xr @ p["wr"].astype(dt))
    out = r * v
    return (
        constrain(out, rules, ("act_batch", "seq", "d_model")),
        {"x_cm": x[:, -1]},
    )


def rwkv_block(
    p, x, cfg: ArchConfig, rules: ShardingRules, norms,
    state: Optional[Dict[str, jax.Array]] = None,
):
    """One RWKV layer: x + TM(norm(x)); x + CM(norm(x)). Returns (x, state)."""
    tm_out, st_tm = time_mix(
        p["tm"], rms_norm(x, norms["ln1"]), cfg, rules, state
    )
    x = x + tm_out
    cm_out, st_cm = channel_mix(
        p["cm"], rms_norm(x, norms["ln2"]), cfg, rules, state
    )
    x = x + cm_out
    return x, {**st_tm, **st_cm}


def init_rwkv_state(
    cfg: ArchConfig, batch: int, heads: Optional[int] = None
) -> Dict[str, jax.Array]:
    d = cfg.d_model
    h = heads or rwkv_heads(cfg)
    return {
        "s": jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        "x_tm": jnp.zeros((batch, d), cfg.compute_dtype),
        "x_cm": jnp.zeros((batch, d), cfg.compute_dtype),
    }


def rwkv_state_dims(cfg: ArchConfig):
    return {
        "s": ("cache_batch", "heads", None, None),
        "x_tm": ("cache_batch", None),
        "x_cm": ("cache_batch", None),
    }
