"""Sharded, atomic, async checkpointing with retention and auto-resume.

Layout::

    <dir>/step_00000420/          # atomic: written as .tmp_, renamed when done
        manifest.json             # tree structure, shapes, dtypes
        leaf_00000.npy ...        # one file per pytree leaf

Writes are atomic (tmp dir + rename), so a preempted job can never see a
torn checkpoint; ``latest_step`` simply picks the largest complete step dir.
``save_async`` snapshots to host memory synchronously (cheap) and writes on a
background thread — the train loop never blocks on disk.

Restore takes a target pytree *of shardings or arrays*: leaves are
``device_put`` with the requested sharding, which is also the elastic-rescale
path (same checkpoint, different mesh → different shardings; see
repro.runtime.elastic).

Production note (1000+-node posture): on a real multi-host cluster each leaf
would be written per-shard (process-local) in OCDBT fashion; the manager's
interface (save/restore against sharding trees) is unchanged — only the I/O
layer widens.  On this single-host container full-array I/O is exact.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(k), v) for k, v in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> str:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp_"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree_util.tree_flatten(host_tree)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host_tree).serialize_using_proto().hex()
            if hasattr(treedef, "serialize_using_proto")
            else None,
            "paths": [p for p, _ in _tree_paths(host_tree)],
            "n_leaves": len(leaves),
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
        }
        for i, leaf in enumerate(leaves):
            # bfloat16 has no portable npy representation: store as f32
            # (lossless upcast), restore via the manifest dtype.
            if str(leaf.dtype) == "bfloat16":
                leaf = np.asarray(leaf, dtype=np.float32)
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._retain()
        return final

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any) -> Any:
        """``target``: pytree of arrays or Shardings with the wanted layout."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        leaves, treedef = jax.tree_util.tree_flatten(target)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["n_leaves"] == len(leaves), (
            f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves)}"
        )
        out = []
        for i, tgt in enumerate(leaves):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            saved_dtype = manifest["dtypes"][i]
            if isinstance(tgt, jax.sharding.Sharding):
                arr = jnp.asarray(arr).astype(saved_dtype)
                out.append(jax.device_put(arr, tgt))
            elif hasattr(tgt, "sharding") and tgt.sharding is not None:
                assert arr.shape == tuple(tgt.shape), (
                    f"leaf {i}: {arr.shape} vs {tgt.shape}"
                )
                arr = jnp.asarray(arr).astype(tgt.dtype)
                out.append(jax.device_put(arr, tgt.sharding))
            else:
                out.append(jnp.asarray(arr).astype(saved_dtype))
        return jax.tree_util.tree_unflatten(treedef, out)
