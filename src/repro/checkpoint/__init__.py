"""Atomic, async, retention-managed checkpointing."""
from .manager import CheckpointManager
