"""Runtime: fault tolerance, straggler watchdog, elastic re-meshing."""
from .fault_tolerance import (
    FaultToleranceConfig, StragglerWatchdog, TrainController, reshard_state,
)
