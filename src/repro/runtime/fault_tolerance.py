"""Fault tolerance: preemption-safe training controller, straggler watchdog,
elastic re-meshing.

1000+-node posture (DESIGN.md §5):

* **Checkpoint/restart** — the controller persists (params, opt_state, step)
  atomically every ``ckpt_every`` steps (async writer) and auto-resumes from
  the newest complete checkpoint; the data pipeline is a pure function of the
  step counter, so a restart replays no data and skips none.
* **Straggler mitigation** — per-step wall-time EMA; a step exceeding
  ``straggler_factor``× the EMA raises a callback (on a real cluster: report
  the slow host to the coordinator for hot-swap; here: counted + logged).
  An optional hard ``step_timeout_s`` aborts the run (supervisor restarts it
  on the surviving nodes — combined with elastic re-meshing below).
* **Elastic re-scale** — ``reshard_state`` moves a checkpointed state tree
  onto a *different* mesh (e.g. data axis 16 → 12 after losing hosts):
  checkpoints are mesh-agnostic (full logical arrays), so restore =
  device_put with the new sharding tree; only batch size / steps-per-epoch
  change, handled by the pure-function data pipeline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class FaultToleranceConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    step_timeout_s: Optional[float] = None
    ema_beta: float = 0.9


class StragglerWatchdog:
    """Wall-clock step monitor with EMA baseline."""

    def __init__(self, cfg: FaultToleranceConfig, on_straggler: Optional[Callable] = None):
        self.cfg = cfg
        self.ema: Optional[float] = None
        self.stragglers = 0
        self.on_straggler = on_straggler
        self.history: list = []  # observed dt per step (injected included)

    def observe(self, dt: float, injected: float = 0.0) -> bool:
        """Record one step's wall time; True when it counts as a straggler.

        ``injected`` adds synthetic latency (fault injection) to the observed
        time without anyone actually sleeping — the serving chaos harness
        uses it to make slow-host detection testable deterministically.
        """
        dt = dt + injected
        self.history.append(dt)
        is_straggler = False
        if self.ema is not None and dt > self.cfg.straggler_factor * self.ema:
            self.stragglers += 1
            is_straggler = True
            if self.on_straggler:
                self.on_straggler(dt, self.ema)
        if self.cfg.step_timeout_s and dt > self.cfg.step_timeout_s:
            raise TimeoutError(f"step took {dt:.1f}s > {self.cfg.step_timeout_s}s")
        # Stragglers do not poison the baseline.
        if self.ema is None:
            self.ema = dt
        elif not is_straggler:
            self.ema = self.cfg.ema_beta * self.ema + (1 - self.cfg.ema_beta) * dt
        return is_straggler


class TrainController:
    """Runs a jitted step function with checkpoint/restart + watchdog.

    ``state`` is any pytree {params, opt_state, ...}; ``step_fn(state, batch,
    step) → (state, metrics)``.  ``make_batch(step)`` must be deterministic in
    ``step`` (restart safety).
    """

    def __init__(
        self,
        step_fn: Callable,
        make_batch: Callable[[int], Any],
        ft: FaultToleranceConfig,
        state_shardings: Optional[Any] = None,
    ):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.ft = ft
        self.ckpt = CheckpointManager(ft.ckpt_dir, keep=ft.keep)
        self.watchdog = StragglerWatchdog(ft)
        self.state_shardings = state_shardings
        self.history: list = []

    def resume_or_init(self, init_state: Any) -> tuple:
        latest = self.ckpt.latest_step()
        if latest is None:
            return init_state, 0
        target = self.state_shardings if self.state_shardings is not None else init_state
        state = self.ckpt.restore(latest, target)
        return state, latest

    def run(
        self,
        init_state: Any,
        n_steps: int,
        preempt_at: Optional[int] = None,
        log_every: int = 10,
        log_fn: Callable = print,
    ) -> Any:
        """Train to ``n_steps`` (absolute). ``preempt_at`` simulates a kill."""
        state, start = self.resume_or_init(init_state)
        for step in range(start, n_steps):
            if preempt_at is not None and step == preempt_at:
                # Simulated preemption: mid-run kill after the last checkpoint.
                raise KeyboardInterrupt(f"simulated preemption at step {step}")
            batch = self.make_batch(step)
            t0 = time.monotonic()
            state, metrics = self.step_fn(state, batch, step)
            jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
            dt = time.monotonic() - t0
            self.watchdog.observe(dt)
            self.history.append({k: float(v) for k, v in metrics.items()})
            if (step + 1) % self.ft.ckpt_every == 0 or step + 1 == n_steps:
                self.ckpt.save_async(step + 1, state)
            if (step + 1) % log_every == 0:
                log_fn(
                    f"step {step+1}: "
                    + " ".join(f"{k}={float(v):.4f}" for k, v in metrics.items())
                    + f" ({dt*1e3:.0f} ms)"
                )
        self.ckpt.wait()
        return state


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------


def reshard_state(state: Any, shardings: Any) -> Any:
    """Move a state tree onto new shardings (new mesh size/layout)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings
    )
