"""qwen1.5-32b — dense GQA(kv=40 = MHA) with QKV bias. [hf:Qwen/Qwen1.5-*].

64L d_model=5120 40H kv=40 d_ff=27392 vocab=152064.  TP-16 pads heads
40->48 (q and kv).  Decode uses an int8 KV cache: bf16 would need ~21.5
GB/chip at decode_32k (64L x 40kv x 128hd x 32k x b128 / 256 chips); int8
packing (the paper's narrow-element argument) halves it under the 16 GB HBM.
FSDP on: 32B params' optimizer state shards over data x model.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    tp_pad_heads=48,
    tp_pad_kv_heads=48,
    shard_kv_heads=True,
    cache_dtype="int8",
    serve_mlp_int8=True,   # w8a16: MLP fits model-sharded, no per-token gathers
    fsdp=True,
    notes="full attention: long_500k skipped",
)
