"""rwkv6-3b "Finch" — attention-free, data-dependent decay. [arXiv:2404.05892].

32L d_model=2560 d_ff=8960 vocab=65536.  40 WKV heads of 64 (padded 48 under
TP-16).  Arch-applicability: no KV/attention indirection exists — packed
streams touch only embedding/head gathers and gradient compression
(DESIGN.md section 4).  long_500k RUNS (O(1) recurrent state).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # d_model / 64 WKV heads
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    ssm="rwkv6",
    tp_pad_heads=48,
    notes="attention-free; long_500k runs; paper technique applies to embedding streams only",
)
