"""qwen2.5-14b — dense GQA with QKV bias. [hf:Qwen/Qwen2.5-*].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.  TP-16 pads q heads
40->48; kv=8 replicated.  FSDP on (AdamW states for 14B exceed 16 GB/chip
under model-only sharding).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    tp_pad_heads=48,
    tp_pad_kv_heads=16,
    shard_kv_heads=True,
    fsdp=True,
    notes="full attention: long_500k skipped",
)
