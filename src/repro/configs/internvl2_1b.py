"""internvl2-1b — InternViT patch frontend (stub) + Qwen2-0.5B-class LM backbone.

[arXiv:2404.16821; hf].  24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The ViT frontend is a stub per the assignment: ``input_specs`` supplies
precomputed patch embeddings (B, 256, 1024) projected into the LM.
TP-16 pads q heads 14->16; kv=2 replicated (2 < 16; KV tensors are tiny).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,           # Qwen2 LM backbone uses QKV bias
    rope_theta=1e6,
    tie_embeddings=True,
    modality="vlm",
    frontend_dim=1024,       # InternViT-300M hidden size
    frontend_len=256,        # patch tokens per image
    tp_pad_heads=16,
    tp_pad_kv_heads=16,
    shard_kv_heads=True,
    notes="full attention: long_500k skipped (no sub-quadratic path)",
)
