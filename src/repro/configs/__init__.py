"""Architecture registry: the 10 assigned archs + reduced smoke variants.

``get_config(name)`` returns the exact assigned configuration;
``smoke_config(name)`` returns a reduced same-family variant (small
layers/width, few experts, tiny vocab) for CPU tests — the full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import SHAPES, ArchConfig, ShapeConfig

from .internvl2_1b import CONFIG as _internvl2_1b
from .qwen1_5_32b import CONFIG as _qwen1_5_32b
from .yi_6b import CONFIG as _yi_6b
from .qwen2_5_14b import CONFIG as _qwen2_5_14b
from .gemma3_27b import CONFIG as _gemma3_27b
from .rwkv6_3b import CONFIG as _rwkv6_3b
from .hubert_xlarge import CONFIG as _hubert_xlarge
from .hymba_1_5b import CONFIG as _hymba_1_5b
from .olmoe_1b_7b import CONFIG as _olmoe_1b_7b
from .arctic_480b import CONFIG as _arctic_480b

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _internvl2_1b,
        _qwen1_5_32b,
        _yi_6b,
        _qwen2_5_14b,
        _gemma3_27b,
        _rwkv6_3b,
        _hubert_xlarge,
        _hymba_1_5b,
        _olmoe_1b_7b,
        _arctic_480b,
    ]
}

ALL_ARCH_NAMES: List[str] = list(ARCHS)


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; available: {ALL_ARCH_NAMES}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config: 2 layers, narrow dims, tiny vocab."""
    c = get_config(name)
    kv = min(c.n_kv_heads, 2)
    heads = 4 if c.ssm != "rwkv6" else 2  # rwkv heads = d/64
    repl = dict(
        n_layers=2,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv if heads % max(kv, 1) == 0 else heads,
        head_dim=32 if c.ssm != "rwkv6" else None,
        d_ff=96 if not c.n_experts else 64,
        vocab=256,
        n_experts=4 if c.n_experts else 0,
        top_k=min(c.top_k, 2) if c.n_experts else 0,
        window=8 if c.window else None,
        global_interval=2 if c.global_interval else None,
        frontend_dim=16 if c.frontend_dim else 0,
        frontend_len=4 if c.frontend_len else 0,
        tp_pad_heads=None,
        tp_pad_kv_heads=None,
        shard_kv_heads=False,
        fsdp=False,
        cache_dtype=c.cache_dtype,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
    return dataclasses.replace(c, **repl)


def applicable_shapes(cfg: ArchConfig) -> List[ShapeConfig]:
    """The shape cells this arch runs (principled skips per DESIGN.md §4)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.causal:  # encoder-only archs have no decode step
        out.append(SHAPES["decode_32k"])
        if cfg.ssm is not None or cfg.window is not None:
            out.append(SHAPES["long_500k"])  # sub-quadratic archs only
    return out
