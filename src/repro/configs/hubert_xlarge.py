"""hubert-xlarge — encoder-only audio transformer. [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (cluster targets).
Bidirectional (causal=False), plain GELU FFN (no GLU).  The conv waveform
frontend is a stub: ``input_specs`` provides frame embeddings (B, S, 512).
Encoder-only => decode_32k and long_500k are SKIPPED (no autoregressive
step).  Framework note: RMSNorm is used in place of LayerNorm (uniform
substrate; recorded in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    glu=False,
    activation="gelu",
    modality="audio",
    frontend_dim=512,
    shard_kv_heads=True,
    notes="encoder-only: decode shapes skipped",
)
