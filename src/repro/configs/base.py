"""Architecture / run configuration.

One :class:`ArchConfig` describes an architecture exactly as assigned (paper
head counts etc.).  TP deployment may *pad* head counts to divide the model
axis (``tp_pad_heads``) — standard practice (cf. MaxText); smoke tests and
non-TP runs use the exact counts.  All padding is recorded here, never
silently applied.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None   # default: d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    activation: str = "silu"
    glu: bool = True                 # gated MLP (SwiGLU/GeGLU); False = plain
    tie_embeddings: bool = False
    causal: bool = True              # False: encoder-only (hubert)

    # sliding-window pattern (gemma3: 5 local : 1 global)
    window: Optional[int] = None
    global_interval: Optional[int] = None  # every k-th layer is global

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    dense_residual: bool = False     # arctic: parallel dense MLP path
    router_aux_coef: float = 0.01

    # SSM / hybrid
    ssm: Optional[str] = None        # 'rwkv6' | 'hymba'
    ssm_state: int = 16
    ssm_conv: int = 4

    # modality stubs
    modality: str = "text"           # text | audio | vlm
    frontend_dim: int = 0            # stub embedding dim (audio/vlm)
    frontend_len: int = 0            # patches/frames per sample

    # deployment
    tp_pad_heads: Optional[int] = None     # padded q-head count under TP
    tp_pad_kv_heads: Optional[int] = None  # padded kv-head count under TP
    shard_kv_heads: bool = False           # shard (padded) kv heads over model
    cache_dtype: str = "bfloat16"          # 'int8' → quantized KV cache
    serve_mlp_int8: bool = False           # w8a16 MLP weights at serving time
    prefill_chunk: int = 0                 # >0: chunked (vLLM-style) prefill
    fsdp: bool = False                     # shard weights over data axis too

    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    optimizer: str = "adamw"         # adafactor for the 480B config
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    grad_compression: bool = False   # int8 error-feedback DP all-reduce

    # notes (applicability, skips) — shown by the launcher
    notes: str = ""

    # ----- derived -----
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def vocab_padded(self, tp: int = 1) -> int:
        """Vocab rounded up to 128 under TP (Megatron-style padding); padded
        classes are masked out of the softmax (models.common)."""
        if tp <= 1:
            return self.vocab
        return -(-self.vocab // 128) * 128

    def heads_for_tp(self, tp: int) -> Tuple[int, int]:
        """(q_heads, kv_heads) actually instantiated under tp-way sharding."""
        if tp <= 1:
            return self.n_heads, self.n_kv_heads
        q = self.tp_pad_heads or self.n_heads
        kv = self.tp_pad_kv_heads or self.n_kv_heads
        assert q % tp == 0, f"{self.name}: q heads {q} not divisible by tp={tp}"
        if self.shard_kv_heads:
            assert kv % tp == 0
        return q, kv

    @property
    def compute_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def layer_is_global(self, i: int) -> bool:
        if self.global_interval is None:
            return True
        return (i % self.global_interval) == (self.global_interval - 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once if tied)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        h, kvh, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * h * hd + 2 * d * kvh * hd + h * hd * d
        if self.ssm == "rwkv6":
            per_layer = 4 * d * d + 2 * d * f // 2 + d * f  # time-mix + channel-mix
        else:
            mlp = (3 if self.glu else 2) * d * f
            if self.n_experts:
                moe = self.n_experts * (3 if self.glu else 2) * d * f
                mlp = moe + (3 * d * f if self.dense_residual else 0) + d * self.n_experts
            per_layer = attn + mlp
            if self.ssm == "hymba":
                per_layer += 2 * d * 2 * d + 2 * d * self.ssm_state * 2
        emb = v * d * (1 if self.tie_embeddings else 2)
        return l * per_layer + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        moe_all = self.n_layers * self.n_experts * (3 if self.glu else 2) * d * f
        moe_active = self.n_layers * self.top_k * (3 if self.glu else 2) * d * f
        return total - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
