"""yi-6b — llama-arch GQA. [arXiv:2403.04652; hf].

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.  Heads divide TP-16
exactly; kv=4 replicated over the model axis (cache is sequence-sharded for
decode so replication costs no HBM capacity at scale).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5e6,
    tp_pad_kv_heads=16,
    shard_kv_heads=True,
    notes="full attention: long_500k skipped",
)
