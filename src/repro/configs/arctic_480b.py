"""arctic-480b — 128-expert top-2 MoE + dense residual MLP. [hf:Snowflake/*].

35L d_model=7168 56H (GQA kv=8) d_ff=4864/expert vocab=32000.  Dense residual
path runs in parallel with the MoE FFN.  TP-16 pads q heads 56->64; kv=8
replicated (decode cache is sequence-sharded).  Adafactor + FSDP: AdamW fp32
states for 480B (~5.8 TB) exceed a 256-chip pod; factored second moment +
(data x model)-sharded states fit (see EXPERIMENTS.md dry-run bytes).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    tp_pad_heads=64,
    tp_pad_kv_heads=16,
    shard_kv_heads=True,
    fsdp=True,
    optimizer="adafactor",
    param_dtype="bfloat16",
    prefill_chunk=4096,    # chunked prefill: bounds MoE dispatch buffers  # f32 params for 480B exceed pod HBM even sharded
    notes="full attention: long_500k skipped",
)
