"""hymba-1.5b — parallel attention + Mamba heads per block. [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001 ssm_state=16.
Each block runs sliding-window attention (1024) in parallel with a selective
SSM head, per-path output-normed and averaged; layers {0, L/2, L-1} use
global attention.  Meta-tokens are out of scope (DESIGN.md).  TP-16 pads
q heads 25->32, kv 5->8 (replicated).  long_500k RUNS (hybrid).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    ssm="hymba",
    ssm_state=16,
    window=1024,
    tp_pad_heads=32,
    tp_pad_kv_heads=16,
    shard_kv_heads=True,
    notes="hybrid: long_500k runs; 3 global-attention layers",
)
