"""olmoe-1b-7b — 64-expert top-8 MoE (1B active / 7B total). [arXiv:2409.02060].

16L d_model=2048 16H (kv=16) d_ff=1024/expert vocab=50304.  The flagship
packed-stream consumer: top-8 dispatch/combine are indirect streams (EP over
the model axis: 64/16 = 4 experts per device).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    shard_kv_heads=True,
    notes="full attention: long_500k skipped",
)
