"""gemma3-27b — 5:1 local:global sliding-window stack, 128k-class context.

[hf:google/gemma-3-*-pt; unverified tier].  62L d_model=5376 32H (GQA kv=16)
head_dim=128 d_ff=21504 vocab=262144.  Every 6th layer is global (traced
flag inside the layer scan); locals use a 1024-token window with RoPE theta
10k, globals theta 1M.  long_500k RUNS: windowed locals keep sub-quadratic
aggregate cost; global-layer KV (~10 layers) shards over the cache_seq axis.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    activation="gelu",
    rope_theta=1e6,
    tie_embeddings=True,
    window=1024,
    global_interval=6,
    shard_kv_heads=True,
    fsdp=True,
    notes="long_500k runs (sliding-window locals + sparse globals)",
)
