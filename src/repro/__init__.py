"""repro: AXI-Pack-inspired packed-irregular-stream framework in JAX."""
__version__ = "0.1.0"
