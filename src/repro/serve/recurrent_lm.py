"""Recurrent model families (RWKV6 / Mamba) behind the serving scheduler.

The transformer serving stack moves a *growing* KV cache through indirect
(page-table) bursts; recurrent architectures invert the memory story: each
sequence owns a **fixed-size** state vector, one slot per resident, laid out
``(layer, slot, *row)`` so a sequence's rows sit at a fixed stride of
``batch`` rows in the flattened pool.  That makes recurrent serving the
strided-burst dialect of AXI-Pack — no memory-resident index vector exists,
the stride in the request descriptor is the whole addressing metadata — and
the natural counterpart to compare against the paged families' indirect
accounting in ``BENCH_serving.json``.

Pieces:

* :class:`RecurrentLM` — a deliberately minimal tied-embedding LM over the
  real :func:`repro.models.rwkv6.rwkv_block` / :func:`repro.models.mamba
  .mamba_fwd` blocks.  **Every** token, prefill or decode, runs through one
  fused ``lax.scan`` program (:meth:`RecurrentLM._steps`) whose per-step
  body is identical regardless of trip count — the property that makes
  scheduler-served output bit-for-bit equal to a direct sequential forward
  at the same batch shape, no matter how chunked prefill and fused decode
  slice the token stream.  Inactive rows carry their state through
  ``jnp.where`` untouched (bit-exact), so batch composition never leaks
  between sequences.
* :class:`RecurrentStatePool` — the donated state pool + host bookkeeping
  (slot ownership, lengths), the recurrent analogue of
  :class:`repro.serve.kv.PagedKVCache`.
* :class:`RecurrentFamily` — the :class:`repro.serve.family.ServableFamily`
  implementation the scheduler drives: slots are the resource unit
  (``units_for(n) == 1``), capacity is unbounded so growth/lookahead never
  fire, eviction-replay re-prefills from a zeroed state row
  (:meth:`RecurrentFamily.replay`, via the strided state-write op), and the
  accounting dialect is :func:`repro.core.packing.recurrent_decode_traffic`
  + :func:`repro.core.streams.recurrent_state_streams`.
* :func:`recurrent_reference_generate` — the direct sequential forward the
  bitwise tests and the serving benchmark compare against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.packing import (
    Traffic,
    recurrent_decode_traffic,
    recurrent_prefill_traffic,
)
from repro.core.streams import recurrent_state_streams
from repro.kernels import ops as kops
from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import Param, init_params, rms_norm, stack_layer_defs
from repro.parallel.sharding import make_rules
from .family import OutOfPages, ServableFamily
from .kv import _donation_noop_ok

__all__ = [
    "RecurrentFamily",
    "RecurrentLM",
    "RecurrentStatePool",
    "recurrent_reference_generate",
]

#: Recurrent slots never grow: a sequence's state footprint is length-free,
#: so the per-slot token capacity is effectively unbounded and the
#: scheduler's growth / lookahead-prealloc machinery is statically idle.
UNBOUNDED_TOKENS = 1 << 62


@dataclasses.dataclass
class RecurrentStatePool:
    """Donated per-sequence state pools + host-side slot bookkeeping.

    ``tensors`` maps state name → a ``(n_layers, batch, *row)`` array (the
    layer-major layout the strided accounting assumes).  Device state is
    functional: every fused launch donates the pools and the family rebinds
    ``tensors``, exactly like the paged cache's page pools.
    """

    tensors: Dict[str, jax.Array]
    lengths_host: np.ndarray  # (batch,) int32 — tokens consumed per slot
    owned: np.ndarray         # (batch,) bool  — slot currently allocated

    @classmethod
    def create(cls, model: "RecurrentLM", batch: int) -> "RecurrentStatePool":
        tensors = {
            name: jnp.zeros((model.cfg.n_layers, batch) + shape, dtype)
            for name, (shape, dtype) in model.state_specs().items()
        }
        return cls(
            tensors=tensors,
            lengths_host=np.zeros((batch,), np.int32),
            owned=np.zeros((batch,), bool),
        )

    @property
    def batch(self) -> int:
        return int(self.lengths_host.shape[0])

    @property
    def n_layers(self) -> int:
        return int(next(iter(self.tensors.values())).shape[0])

    @property
    def n_free(self) -> int:
        return int(self.batch - self.owned.sum())

    @property
    def row_bytes(self) -> Tuple[int, ...]:
        """Per-layer row footprint of each state tensor (stream elements)."""
        lb = self.n_layers * self.batch
        return tuple(int(t.nbytes) // lb for t in self.tensors.values())

    @property
    def state_slot_bytes(self) -> int:
        """Bytes of one sequence's full state (all layers, all tensors)."""
        return sum(int(t.nbytes) // self.batch for t in self.tensors.values())

    @property
    def pool_bytes(self) -> int:
        return sum(int(t.nbytes) for t in self.tensors.values())


class RecurrentLM:
    """Minimal tied-embedding LM over real RWKV6 / Mamba blocks.

    Mirrors :class:`repro.serve.paged_lm.PagedLM`'s austerity (float32
    params, no final norm, greedy-friendly) so every per-token computation
    is row-wise — a sequence's outputs depend only on its own tokens and
    state rows, the property the scheduler's bitwise-equivalence guarantees
    rest on.  ``arch`` picks the block: ``'rwkv6'`` (wkv state per head) or
    ``'mamba'`` (SSM state + conv tail); both share all pool plumbing.
    """

    def __init__(self, cfg: ArchConfig, key: jax.Array,
                 arch: Optional[str] = None, impl: str = "pallas"):
        arch = arch or ("rwkv6" if cfg.ssm == "rwkv6" else "mamba")
        if arch not in ("rwkv6", "mamba"):
            raise ValueError(f"unknown recurrent arch: {arch!r}")
        if arch == "rwkv6" and cfg.d_model % rwkv_mod.HEAD_DIM:
            raise ValueError(
                f"rwkv6 needs d_model divisible by {rwkv_mod.HEAD_DIM}"
            )
        if arch == "mamba" and cfg.ssm_conv < 2:
            raise ValueError("mamba needs ssm_conv >= 2 (a conv state tail)")
        self.cfg = cfg
        self.arch = arch
        self.impl = impl
        self.rules = make_rules()
        d = cfg.d_model
        k_embed, k_layers = jax.random.split(key)
        self.embed = (
            jax.random.normal(k_embed, (cfg.vocab, d), jnp.float32) * 0.02
        )
        norm = lambda: Param((d,), ("d_model",), init="zeros")
        if arch == "rwkv6":
            ldefs: Dict[str, Any] = {
                **rwkv_mod.rwkv_defs(cfg), "ln1": norm(), "ln2": norm(),
            }
        else:
            ldefs = {"mamba": mamba_mod.mamba_defs(cfg), "ln": norm()}
        self.layers = init_params(stack_layer_defs(ldefs, cfg.n_layers),
                                  k_layers)

    def bind(self, pool: RecurrentStatePool) -> "RecurrentFamily":
        """Wrap this model + ``pool`` as the scheduler-facing family."""
        return RecurrentFamily(self, pool)

    def init_pool(self, batch: int) -> RecurrentStatePool:
        return RecurrentStatePool.create(self, batch)

    def state_specs(self) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
        """State name → (per-slot per-layer row shape, dtype)."""
        cfg = self.cfg
        d, hd = cfg.d_model, rwkv_mod.HEAD_DIM
        dt = cfg.compute_dtype
        if self.arch == "rwkv6":
            h = rwkv_mod.rwkv_heads(cfg)
            return {
                "s": ((h, hd, hd), jnp.float32),
                "x_tm": ((d,), dt),
                "x_cm": ((d,), dt),
            }
        di, _, n = mamba_mod.mamba_dims(cfg)
        return {
            "h": ((di, n), jnp.float32),
            "conv": ((cfg.ssm_conv - 1, d * 2), dt),
        }

    def _layer_step(self, p_l, x, st_l):
        """One layer over a (B, 1, D) slice; returns (x, new_layer_state)."""
        if self.arch == "rwkv6":
            return rwkv_mod.rwkv_block(p_l, x, self.cfg, self.rules, p_l, st_l)
        out, ns = mamba_mod.mamba_fwd(
            p_l["mamba"], rms_norm(x, p_l["ln"]), self.cfg, self.rules,
            st_l, chunk=1,
        )
        return x + out, ns

    @functools.cached_property
    def _steps(self):
        """The one fused token-step program (prefill *and* decode).

        ``lax.scan`` over ``n`` per-token steps; the body embeds the step's
        token (given for prefill, the carried greedy argmax for decode),
        runs every layer, and masks state write-back by ``active`` so
        inactive rows are bit-exact no-ops.  One body → one program shape
        per ``n``; scan bodies compile identically for every trip count, so
        any chunking of a token stream produces identical bits.
        """
        cfg, vocab = self.cfg, self.cfg.vocab
        n_layers = cfg.n_layers

        def run(layers, embed, pool, cur, toks, use_input, active):
            # pool: name → (L, B, *row); cur (B,) i32 carried token;
            # toks (n, B) i32; use_input (n,) bool; active (n, B) bool.
            def body(carry, xs):
                pool_c, cur_c = carry
                tok_in, use_in, act = xs
                tok = jnp.where(use_in, tok_in, cur_c)
                x = embed[tok][:, None, :].astype(cfg.compute_dtype)
                new_states = []
                for l in range(n_layers):
                    p_l = jax.tree.map(lambda a: a[l], layers)
                    st_l = {k: pool_c[k][l] for k in pool_c}
                    x, ns = self._layer_step(p_l, x, st_l)
                    new_states.append(ns)
                new_pool = {
                    k: jnp.stack([ns[k] for ns in new_states])
                    for k in pool_c
                }
                new_pool = {
                    k: jnp.where(
                        act.reshape((1, -1) + (1,) * (new_pool[k].ndim - 2)),
                        new_pool[k], pool_c[k],
                    )
                    for k in pool_c
                }
                logits = x[:, 0].astype(jnp.float32) @ embed.T  # (B, V)
                nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
                cur_new = jnp.where(act, nxt, cur_c)
                return (new_pool, cur_new), (logits, cur_new)

            (pool_f, cur_f), (logits, toks_out) = jax.lax.scan(
                body, (pool, cur), (toks, use_input, active)
            )
            return pool_f, cur_f, logits, toks_out

        return jax.jit(run, donate_argnums=(2,))

    def prefill_chunk(self, tensors, toks, active):
        """Feed given tokens: toks (C, B) i32, active (C, B) bool.

        Returns (new tensors, per-step logits (C, B, vocab) on device).
        """
        c, b = toks.shape
        cur = np.zeros((b,), np.int32)
        use = np.ones((c,), bool)
        with _donation_noop_ok():
            tensors, _, logits, _ = self._steps(
                self.layers, self.embed, tensors, cur, toks, use, active
            )
        return tensors, logits

    def decode_chain(self, tensors, tokens, active, n: int):
        """Decode ``n`` greedy steps from current tokens; active (B,) bool.

        Power-of-two chaining (like ``PagedLM.decode_upto``) bounds the
        compiled-program count to O(log n); scan trip-count invariance makes
        the chain bit-identical to ``n`` single steps.  Returns
        (new tensors, (n, B) host tokens).
        """
        b = active.shape[0]
        cur = np.asarray(tokens, np.int32)
        outs: List[np.ndarray] = []
        rem = int(n)
        while rem:
            m = 1 << (rem.bit_length() - 1)
            toks = np.zeros((m, b), np.int32)
            use = np.zeros((m,), bool)
            act = np.broadcast_to(np.asarray(active, bool), (m, b))
            with _donation_noop_ok():
                tensors, cur, _, toks_out = self._steps(
                    self.layers, self.embed, tensors, cur, toks, use, act
                )
            outs.append(np.asarray(toks_out))
            rem -= m
        return tensors, np.concatenate(outs, axis=0)


class RecurrentFamily(ServableFamily):
    """Serve a :class:`RecurrentLM` out of a :class:`RecurrentStatePool`.

    The resource unit is the state *slot*: every sequence costs exactly one
    unit regardless of length (``units_for``), capacity never binds
    (``token_capacity`` is unbounded), and ``grow``/``trim`` are statically
    idle.  Eviction-replay is the same protocol the paged family uses —
    release the unit, re-admit, re-prefill — except the device half of the
    reset is explicit: :meth:`replay` zeroes the slot's state rows through
    the strided scatter op, since donated pools recycle rows across
    occupants.
    """

    def __init__(self, model: RecurrentLM, pool: RecurrentStatePool):
        want = set(model.state_specs())
        have = set(pool.tensors)
        if want != have:
            raise ValueError(
                f"state pool tensors {sorted(have)} do not match the "
                f"model's state layout {sorted(want)}: create the pool "
                f"with RecurrentStatePool.create(model, batch)"
            )
        self.model = model
        self.pool = pool
        self.name = model.arch

    # -- geometry -----------------------------------------------------------

    @property
    def batch(self) -> int:
        return self.pool.batch

    @property
    def vocab(self) -> int:
        return self.model.cfg.vocab

    @property
    def total_units(self) -> int:
        return self.pool.batch

    @property
    def free_units(self) -> int:
        return self.pool.n_free

    @property
    def slot_token_capacity(self) -> int:
        return UNBOUNDED_TOKENS

    @property
    def pool_bytes(self) -> int:
        return self.pool.pool_bytes

    def units_for(self, n_tokens: int) -> int:
        return 1 if n_tokens > 0 else 0

    def mapped_units(self, slot: int) -> int:
        return 1 if self.pool.owned[slot] else 0

    def token_capacity(self, slot: int) -> int:
        return UNBOUNDED_TOKENS if self.pool.owned[slot] else 0

    def state_bytes(self, n_tokens: int) -> int:
        return self.pool.state_slot_bytes if n_tokens > 0 else 0

    def lengths(self) -> np.ndarray:
        return self.pool.lengths_host

    # -- slot lifecycle -----------------------------------------------------

    def alloc_state(self, slot: int, units: int) -> None:
        if units <= 0:
            return
        if self.pool.owned[slot]:
            raise OutOfPages(f"slot {slot} is already allocated")
        if units > 1 or self.pool.n_free < 1:
            raise OutOfPages(
                f"need {units} state slot(s), {self.pool.n_free} free"
            )
        self.pool.owned[slot] = True
        self.pool.lengths_host[slot] = 0

    def release(self, slot: int) -> None:
        # Host bookkeeping only; the stale device rows are zeroed by the
        # next occupant's replay() at admission.
        self.pool.owned[slot] = False
        self.pool.lengths_host[slot] = 0

    def replay(self, slot: int) -> None:
        """Zero the slot's state rows (strided scatter) — fresh-prefill
        semantics for recycled donated pools; called at every admission."""
        for name, t in self.pool.tensors.items():
            zeros = jnp.zeros((t.shape[0],) + t.shape[2:], t.dtype)
            self.pool.tensors[name] = kops.recurrent_state_write(
                t, int(slot), zeros, impl=self.model.impl
            )
        self.pool.lengths_host[slot] = 0

    # -- compute ------------------------------------------------------------

    def prefill_batch(self, tokens: np.ndarray, counts: np.ndarray,
                      slots: np.ndarray, starts: np.ndarray) -> np.ndarray:
        b = self.batch
        n_rows, c = tokens.shape
        toks = np.zeros((c, b), np.int32)
        act = np.zeros((c, b), bool)
        for i in range(n_rows):
            ci, si = int(counts[i]), int(slots[i])
            toks[:ci, si] = tokens[i, :ci]
            act[:ci, si] = True
        self.pool.tensors, logits = self.model.prefill_chunk(
            self.pool.tensors, toks, act
        )
        lg = np.asarray(logits)  # (C, B, vocab)
        out = lg[np.maximum(np.asarray(counts, np.int64) - 1, 0),
                 np.asarray(slots, np.int64)]
        # Scalar loop: padding rows alias slot 0 with count 0, and fancy
        # `+=` drops duplicate-index updates instead of accumulating them.
        for i in range(n_rows):
            self.pool.lengths_host[int(slots[i])] += int(counts[i])
        return out

    def decode_steps(self, tokens: np.ndarray, active: np.ndarray,
                     n: int) -> np.ndarray:
        self.pool.tensors, out = self.model.decode_chain(
            self.pool.tensors, tokens, active, n
        )
        self.pool.lengths_host[np.asarray(active, bool)] += int(n)
        return out

    # -- accounting ---------------------------------------------------------

    def step_streams(self, active: np.ndarray,
                     n: int) -> List[Tuple[Traffic, tuple]]:
        slots = [int(s) for s in np.nonzero(np.asarray(active, bool))[0]]
        traffic = recurrent_decode_traffic(
            len(slots), self.batch, self.pool.state_slot_bytes
        )
        streams = recurrent_state_streams(
            slots, self.batch, self.pool.n_layers, self.pool.row_bytes
        )
        # State size is length-free, so every fused step moves identical
        # bytes — one record shared n times, like a step-at-a-time run.
        return [(traffic, streams)] * int(n)

    def prefill_account(self, slots: np.ndarray, starts: np.ndarray,
                        counts: np.ndarray) -> Tuple[Traffic, tuple]:
        traffic = recurrent_prefill_traffic(
            counts, self.batch, self.pool.state_slot_bytes
        )
        streams = recurrent_state_streams(
            [int(s) for s in slots], self.batch, self.pool.n_layers,
            self.pool.row_bytes,
        )
        return traffic, streams

    # -- invariants ---------------------------------------------------------

    def check_integrity(self, retained: int = 0) -> None:
        if retained:
            raise ValueError(
                f"recurrent family cannot hold {retained} retained prefix "
                f"entries (no prefix sharing)"
            )
        pool = self.pool
        if pool.lengths_host.shape != (pool.batch,):
            raise ValueError("lengths shadow shape mismatch")
        bad = np.nonzero(~pool.owned & (pool.lengths_host != 0))[0]
        if bad.size:
            raise ValueError(
                f"free slots {bad.tolist()} have nonzero lengths"
            )
        if (pool.lengths_host < 0).any():
            raise ValueError("negative slot length")
        for name, t in pool.tensors.items():
            if t.shape[:2] != (pool.n_layers, pool.batch):
                raise ValueError(
                    f"state tensor {name!r} has pool shape {t.shape[:2]}, "
                    f"want {(pool.n_layers, pool.batch)}"
                )


def recurrent_reference_generate(
    model: RecurrentLM,
    pool: RecurrentStatePool,
    prompts: Sequence[Sequence[int]],
    max_new: int,
    chunk: int = 8,
) -> List[List[int]]:
    """Direct sequential forward: the serving-free ground truth.

    Drives the same fused step program over the same batch shape — prompt
    tokens per-position with row masks, then greedy decode — with no
    scheduler in the loop.  Scan-chunking invariance and bit-exact row
    masking make the result identical to any scheduler interleaving, so
    tests and the benchmark assert bitwise equality against this.
    """
    b = pool.batch
    if len(prompts) > b:
        raise ValueError(f"{len(prompts)} prompts > batch {b}")
    plens = [len(p) for p in prompts]
    if min(plens, default=1) < 1:
        raise ValueError("empty prompt")
    tensors = pool.tensors
    maxp = max(plens)
    last = np.zeros((len(prompts), model.cfg.vocab), np.float32)
    pos = 0
    while pos < maxp:
        c = min(chunk, maxp - pos)
        toks = np.zeros((c, b), np.int32)
        act = np.zeros((c, b), bool)
        for i, p in enumerate(prompts):
            ci = min(max(plens[i] - pos, 0), c)
            if ci:
                toks[:ci, i] = p[pos:pos + ci]
                act[:ci, i] = True
        tensors, logits = model.prefill_chunk(tensors, toks, act)
        lg = np.asarray(logits)
        for i in range(len(prompts)):
            if pos < plens[i] <= pos + c:
                last[i] = lg[plens[i] - pos - 1, i, :model.cfg.vocab]
        pos += c
    out = [[int(np.argmax(last[i]))] for i in range(len(prompts))]
    if max_new > 1:
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for i, o in enumerate(out):
            tokens[i] = o[0]
            active[i] = True
        tensors, steps = model.decode_chain(tensors, tokens, active,
                                            max_new - 1)
        for i, o in enumerate(out):
            o.extend(int(steps[s, i]) for s in range(max_new - 1))
    pool.tensors = tensors
    return out
