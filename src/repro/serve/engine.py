"""Serving engine: batched prefill + decode with contiguous or paged KV.

Two cache strategies:

* **Contiguous** (``lm.init_cache``) — what the dry-run decode cells lower:
  cache sequence sharded over 'model' (flash-decoding SP).
* **Paged** (this module) — fixed-size pages + per-sequence page tables; the
  page table is the AXI-Pack indirect stream descriptor and decode attention
  runs through the ``paged_decode_attention`` kernel (scalar-prefetched page
  ids → direct HBM page DMAs).  Used by examples/serve_decode.py and the
  batching tests; pages admit continuous batching (sequences of different
  lengths enter/leave without reshaping the pool).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models import lm
from repro.models.common import rms_norm
from repro.parallel.sharding import ShardingRules


@dataclasses.dataclass
class PagedKVCache:
    """Physical page pool + per-sequence page tables (one per layer stack)."""

    k_pages: jax.Array     # (L, P, page, KVH, hd)
    v_pages: jax.Array
    page_table: jax.Array  # (B, n_pages) physical ids
    lengths: jax.Array     # (B,)
    free: List[int]

    @classmethod
    def create(cls, cfg: ArchConfig, batch: int, max_len: int, page: int = 64,
               tp: int = 1):
        q_heads, kv_heads = cfg.heads_for_tp(tp)
        n_pages_seq = max_len // page
        pool = batch * n_pages_seq
        dt = cfg.compute_dtype
        return cls(
            k_pages=jnp.zeros((cfg.n_layers, pool, page, kv_heads, cfg.hd), dt),
            v_pages=jnp.zeros((cfg.n_layers, pool, page, kv_heads, cfg.hd), dt),
            page_table=jnp.zeros((batch, n_pages_seq), jnp.int32),
            lengths=jnp.zeros((batch,), jnp.int32),
            free=list(range(pool)),
        )

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    def allocate(self, seq: int, n_pages: int) -> "PagedKVCache":
        """Host-side page allocation for one sequence (continuous batching)."""
        ids = [self.free.pop() for _ in range(n_pages)]
        pt = np.array(self.page_table)  # writable host copy
        pt[seq, :n_pages] = ids
        return dataclasses.replace(self, page_table=jnp.asarray(pt))

    def release(self, seq: int) -> "PagedKVCache":
        pt = np.asarray(self.page_table)
        ln = int(np.asarray(self.lengths)[seq])
        used = (ln + self.page_size - 1) // self.page_size
        self.free.extend(int(p) for p in pt[seq, :used])
        lengths = np.array(self.lengths)
        lengths[seq] = 0
        return dataclasses.replace(self, lengths=jnp.asarray(lengths))


class ServeEngine:
    """Minimal production-shaped engine: prefill, batched greedy decode."""

    def __init__(self, cfg: ArchConfig, params, rules: ShardingRules,
                 max_len: int = 512, batch: int = 8, impl: str = "pallas"):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.max_len = max_len
        self.impl = impl
        self.cache = lm.init_cache(cfg, batch, max_len)
        self._prefill = jax.jit(
            lambda p, b, c: lm.prefill(p, b, c, cfg, rules)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, rules)
        )

    def generate(
        self, prompts: jax.Array, n_new: int, greedy: bool = True,
        rng: Optional[jax.Array] = None,
    ) -> np.ndarray:
        """prompts (B, S0) int32 → (B, n_new) generated ids."""
        b, s0 = prompts.shape
        logits, self.cache = self._prefill(
            self.params, {"tokens": prompts}, self.cache
        )
        out = []
        tok = self._sample(logits[:, 0], greedy, rng, 0)
        for i in range(n_new):
            out.append(tok)
            logits, self.cache = self._decode(
                self.params, tok[:, None], self.cache, s0 + i
            )
            tok = self._sample(logits, greedy, rng, i + 1)
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits, greedy, rng, step):
        logits = logits[..., : self.cfg.vocab]  # drop TP padding classes
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(rng, step)
        return jax.random.categorical(key, logits).astype(jnp.int32)
