"""Serving engine: batched prefill + decode with contiguous or paged KV.

Two cache strategies:

* **Contiguous** (``lm.init_cache``) — what the dry-run decode cells lower:
  cache sequence sharded over 'model' (flash-decoding SP).
* **Paged** (this module) — fixed-size pages + per-sequence page tables; the
  page table is the AXI-Pack indirect stream descriptor and decode attention
  runs through the ``paged_decode_attention`` kernel (scalar-prefetched page
  ids → direct HBM page DMAs).  Used by examples/serve_decode.py and the
  batching tests; pages admit continuous batching (sequences of different
  lengths enter/leave without reshaping the pool).

The paged path is built as a *device-resident fast path*: the page pools are
donated into every jitted call (``donate_argnums``) so they update in place
instead of being copied per step, greedy sampling happens on device, and
``decode_steps`` fuses ``n`` decode iterations into one ``lax.scan`` launch
that feeds its own samples back — the host only sees tokens when the
scheduler reaches a scheduling boundary (admission, page growth,
retirement).  Host-side shadow state (``lengths_host``/``page_table_host``)
lets all bookkeeping and traffic accounting run without a single
device→host sync on the hot path.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import warnings
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models import lm
from repro.models.common import rms_norm
from repro.parallel.sharding import ShardingRules

class OutOfPages(RuntimeError):
    """Raised when a page allocation cannot be satisfied from the free pool."""


@contextlib.contextmanager
def _donation_noop_ok():
    """Silence jax's donation-unusable warning for one library dispatch.

    Pool donation is a deliberate no-op on CPU backends and the fast path is
    identical either way, so the warning is noise *for these calls only* —
    the suppression is scoped with ``catch_warnings`` so user code's own
    donation diagnostics (where a failed donation is a real memory bug) are
    never swallowed."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pool_page(pool: jax.Array, src, dst) -> jax.Array:
    """``pool[:, dst] = pool[:, src]`` across all layers, in place.

    ``src``/``dst`` are traced scalars, so every copy-on-write page copy
    reuses one compiled program per pool shape/dtype; donation lets XLA
    alias the update into the resident pool instead of cloning it.
    """
    return pool.at[:, dst].set(pool[:, src])


@dataclasses.dataclass
class PagedKVCache:
    """Physical page pool + per-sequence page tables (one per layer stack).

    The dataclass is *functional*: ``allocate``/``release`` copy every piece
    of host bookkeeping they touch before writing (``free``, ``mapped``,
    ``lengths_host``, ``page_table_host``, ``refcounts``) and return a new
    cache, so a retained older cache object is never corrupted by later
    calls.  (Exception: :meth:`ensure_writable` dispatches device page
    copies with the pools donated, matching the contract of every jitted
    model entry point — after calling it, the old cache's device arrays
    must not be reused.)

    ``refcounts`` makes pages shareable: each physical page counts its
    owners (page-table mappings plus prefix-index retentions) and is
    returned to ``free`` only when the count hits zero.  ``share`` maps
    another sequence's pages by refcount bump, ``ensure_writable`` performs
    copy-on-write before a shared page is written, and
    ``retain_pages``/``release_pages`` hold pages alive for a prompt-prefix
    index without any slot mapping them.

    ``lengths_host``/``page_table_host`` are host-side shadows of the device
    arrays, maintained by :class:`PagedLM` and ``allocate``/``release``; the
    scheduler reads them instead of syncing device state on the hot path.

    ``kv_dtype='int8'`` allocates int8 K/V pools plus fp32 *scale pools*
    (``k_scale``/``v_scale``, shape (L, P, page, KVH) — one scale per page
    token slot per KV head, the layout of ``ref.quantize_kv``).  The scale
    pools are donated alongside the K/V pools in every jitted entry point,
    and page bookkeeping (allocate/trim/release) needs no extra work: a
    physical page owns its scale rows, so remapping the page remaps its
    scales — eviction/replay rebuilds both bit-for-bit through the same
    quantize-on-write ops.
    """

    k_pages: jax.Array     # (L, P, page, KVH, hd) — int8 codes in int8 mode
    v_pages: jax.Array
    page_table: jax.Array  # (B, n_pages) physical ids
    lengths: jax.Array     # (B,)
    free: List[int]
    mapped: Optional[np.ndarray] = None  # (B,) pages currently mapped per slot
    lengths_host: Optional[np.ndarray] = None      # (B,) int32 shadow
    page_table_host: Optional[np.ndarray] = None   # (B, n_pages) int32 shadow
    k_scale: Optional[jax.Array] = None  # (L, P, page, KVH) fp32, int8 mode
    v_scale: Optional[jax.Array] = None
    refcounts: Optional[np.ndarray] = None  # (P,) owners per physical page

    #: kv_dtype name → pool dtype (None = the config's compute dtype).
    KV_DTYPES = {
        "fp32": jnp.float32, "float32": jnp.float32,
        "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
        "int8": jnp.int8,
    }

    @classmethod
    def create(cls, cfg: ArchConfig, batch: int, max_len: int, page: int = 64,
               tp: int = 1, pool_pages: Optional[int] = None,
               kv_dtype=None):
        """``kv_dtype`` is a name from :attr:`KV_DTYPES`, an actual dtype
        (e.g. a :class:`PagedLM`'s ``kv_dtype``, guaranteeing model/cache
        agreement), or ``None`` for the config's compute dtype."""
        q_heads, kv_heads = cfg.heads_for_tp(tp)
        n_pages_seq = max_len // page
        pool = pool_pages if pool_pages is not None else batch * n_pages_seq
        if kv_dtype is None:
            dt = cfg.compute_dtype
        elif isinstance(kv_dtype, str):
            dt = cls.KV_DTYPES[kv_dtype]
        else:
            dt = jnp.dtype(kv_dtype).type
        shape = (cfg.n_layers, pool, page, kv_heads, cfg.hd)
        quantized = dt == jnp.int8
        # Scale init of 1.0 matches ref.int8_quantize on all-zero rows, so an
        # unwritten page dequantizes to exact zeros either way.
        return cls(
            k_pages=jnp.zeros(shape, dt),
            v_pages=jnp.zeros(shape, dt),
            page_table=jnp.zeros((batch, n_pages_seq), jnp.int32),
            lengths=jnp.zeros((batch,), jnp.int32),
            free=list(range(pool)),
            mapped=np.zeros((batch,), np.int64),
            lengths_host=np.zeros((batch,), np.int32),
            page_table_host=np.zeros((batch, n_pages_seq), np.int32),
            k_scale=jnp.ones(shape[:-1], jnp.float32) if quantized else None,
            v_scale=jnp.ones(shape[:-1], jnp.float32) if quantized else None,
            refcounts=np.zeros((pool,), np.int64),
        )

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def pool_bytes(self) -> int:
        """Device bytes held by the K/V pools (scale pools included)."""
        total = self.k_pages.nbytes + self.v_pages.nbytes
        if self.quantized:
            total += self.k_scale.nbytes + self.v_scale.nbytes
        return total

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def pages_per_seq(self) -> int:
        return self.page_table.shape[1]

    @property
    def total_pages(self) -> int:
        return self.k_pages.shape[1]

    @property
    def n_free(self) -> int:
        return len(self.free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _mapped(self, seq: int) -> int:
        if self.mapped is not None:
            return int(self.mapped[seq])
        if self.lengths_host is not None:
            return self.pages_for(int(self.lengths_host[seq]))
        ln = int(np.asarray(self.lengths)[seq])
        return self.pages_for(ln)

    def _host_table(self) -> np.ndarray:
        if self.page_table_host is not None:
            return np.array(self.page_table_host)
        return np.array(self.page_table)

    def _drop_ref(self, refs: Optional[np.ndarray], free: List[int],
                  page: int) -> None:
        """Drop one owner of ``page``; free it when no owners remain.

        With no refcount array (legacy caches built before sharing) every
        page has exactly one owner and the drop is an immediate free.
        """
        if refs is None:
            free.append(page)
            return
        refs[page] -= 1
        if refs[page] < 0:
            raise AssertionError(f"page {page} refcount went negative")
        if refs[page] == 0:
            free.append(page)

    def allocate(self, seq: int, n_pages: int) -> "PagedKVCache":
        """Map ``n_pages`` new physical pages after the slot's current ones."""
        if n_pages > len(self.free):
            raise OutOfPages(
                f"seq {seq} needs {n_pages} pages, {len(self.free)} free"
            )
        start = self._mapped(seq)
        if start + n_pages > self.pages_per_seq:
            raise OutOfPages(
                f"seq {seq}: {start}+{n_pages} pages exceeds the "
                f"{self.pages_per_seq}-page table row"
            )
        free = list(self.free)
        ids = [free.pop() for _ in range(n_pages)]
        refs = None if self.refcounts is None else self.refcounts.copy()
        if refs is not None:
            for p in ids:
                refs[p] = 1
        pt = self._host_table()
        pt[seq, start:start + n_pages] = ids
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = start + n_pages
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            free=free, mapped=mapped, refcounts=refs,
        )

    def trim(self, seq: int, keep_pages: int) -> "PagedKVCache":
        """Unmap a slot's pages beyond ``keep_pages``.

        Only meaningful for pages past the written content (lookahead
        over-provisioning): trimmed pages hold no live KV *for this slot*,
        so remapping them later on demand is loss-free.  A trimmed page
        still referenced elsewhere (a prefix sibling or the prefix index)
        is only un-mapped here — it returns to the free pool when its last
        owner drops it.
        """
        used = self._mapped(seq)
        if keep_pages >= used:
            return self
        pt = self._host_table()
        free = list(self.free)
        refs = None if self.refcounts is None else self.refcounts.copy()
        for p in pt[seq, keep_pages:used]:
            self._drop_ref(refs, free, int(p))
        pt[seq, keep_pages:used] = 0
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = keep_pages
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            free=free, mapped=mapped, refcounts=refs,
        )

    def release(self, seq: int) -> "PagedKVCache":
        """Drop a slot's page mappings (sequence exit / eviction).

        Each page loses this slot as an owner; pages with no remaining
        owners return to the free pool.
        """
        pt = self._host_table()
        used = self._mapped(seq)
        free = list(self.free)
        refs = None if self.refcounts is None else self.refcounts.copy()
        for p in pt[seq, :used]:
            self._drop_ref(refs, free, int(p))
        pt[seq, :] = 0
        if self.lengths_host is not None:
            lengths = self.lengths_host.copy()
        else:
            lengths = np.array(self.lengths)
        lengths[seq] = 0
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = 0
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            lengths=jnp.asarray(lengths),
            lengths_host=lengths if self.lengths_host is not None else None,
            free=free, mapped=mapped, refcounts=refs,
        )

    # -- prefix sharing ------------------------------------------------------

    def share(self, seq: int, page_ids: List[int]) -> "PagedKVCache":
        """Map already-populated physical pages into ``seq`` by refcount bump.

        The pages' KV contents are untouched — the new sequence reads the
        prefix another sequence prefilled.  Writes into a shared page must
        go through :meth:`ensure_writable` first.
        """
        if not page_ids:
            return self
        if self.refcounts is None:
            raise ValueError("share() requires a refcounted cache")
        start = self._mapped(seq)
        if start + len(page_ids) > self.pages_per_seq:
            raise OutOfPages(
                f"seq {seq}: {start}+{len(page_ids)} shared pages exceeds "
                f"the {self.pages_per_seq}-page table row"
            )
        refs = self.refcounts.copy()
        for p in page_ids:
            if refs[p] <= 0:
                raise AssertionError(f"cannot share unowned page {p}")
            refs[p] += 1
        pt = self._host_table()
        pt[seq, start:start + len(page_ids)] = page_ids
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = start + len(page_ids)
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            mapped=mapped, refcounts=refs,
        )

    def retain_pages(self, page_ids: List[int]) -> "PagedKVCache":
        """Add one owner to each page (prefix-index retention)."""
        if not page_ids:
            return self
        if self.refcounts is None:
            raise ValueError("retain_pages() requires a refcounted cache")
        refs = self.refcounts.copy()
        for p in page_ids:
            if refs[p] <= 0:
                raise AssertionError(f"cannot retain unowned page {p}")
            refs[p] += 1
        return dataclasses.replace(self, refcounts=refs)

    def release_pages(self, page_ids: List[int]) -> "PagedKVCache":
        """Drop one owner from each page; zero-owner pages return to free."""
        if not page_ids:
            return self
        if self.refcounts is None:
            raise ValueError("release_pages() requires a refcounted cache")
        refs = self.refcounts.copy()
        free = list(self.free)
        for p in page_ids:
            self._drop_ref(refs, free, int(p))
        return dataclasses.replace(self, refcounts=refs, free=free)

    def check_integrity(self, retained: int = 0) -> None:
        """Assert the pool's host-side bookkeeping is self-consistent.

        ``retained`` is the number of out-of-table owners (prefix-index
        retentions) the refcount conservation law must account for.  Checks
        — all host-side, no device sync:

        * the free list holds no duplicates and only valid page ids;
        * no page is simultaneously free and owned, and free + owned
          partition the pool (refcounted caches);
        * conservation: ``refcounts.sum() == mapped.sum() + retained``;
        * every mapped page-table entry points at an owned page, and
          entries beyond ``mapped`` are zeroed (no orphaned host shadows);
        * ``lengths_host`` never exceeds the mapped capacity of its slot.

        Raises ``AssertionError`` on the first violation; the chaos suite
        (``repro.serve.faults``) calls this after every scheduler step.
        """
        free = list(self.free)
        assert len(free) == len(set(free)), "duplicate pages in free list"
        assert all(0 <= p < self.total_pages for p in free), \
            f"free list holds out-of-range page: {free}"
        refs = self.refcounts
        table = self.page_table_host
        if refs is not None:
            assert (refs >= 0).all(), "negative refcount"
            owned = {p for p in range(self.total_pages) if refs[p] > 0}
            overlap = owned & set(free)
            assert not overlap, f"pages both free and owned: {sorted(overlap)}"
            assert len(owned) + len(free) == self.total_pages, (
                f"free ({len(free)}) + owned ({len(owned)}) pages do not "
                f"partition the {self.total_pages}-page pool"
            )
            if self.mapped is not None:
                assert int(refs.sum()) == int(self.mapped.sum()) + retained, (
                    f"refcount conservation broken: refs {int(refs.sum())} "
                    f"!= mapped {int(self.mapped.sum())} + retained {retained}"
                )
        if table is not None and self.mapped is not None:
            for seq in range(table.shape[0]):
                used = int(self.mapped[seq])
                for p in table[seq, :used]:
                    assert int(p) not in set(free), \
                        f"seq {seq} maps free page {int(p)}"
                    if refs is not None:
                        assert refs[int(p)] >= 1, \
                            f"seq {seq} maps unowned page {int(p)}"
                assert not table[seq, used:].any(), (
                    f"seq {seq}: orphaned table entries beyond its "
                    f"{used} mapped pages"
                )
                if self.lengths_host is not None:
                    ln = int(self.lengths_host[seq])
                    assert ln <= used * self.page_size, (
                        f"seq {seq}: length shadow {ln} exceeds "
                        f"{used} mapped pages"
                    )

    def ensure_writable(self, seq: int, lo_token: int,
                        hi_token: int) -> Tuple["PagedKVCache", int]:
        """Copy-on-write any shared page covering tokens [lo, hi] of ``seq``.

        Pages in the token range with more than one owner are copied to
        fresh physical pages (K/V pools and, in int8 mode, the scale pools
        — the codes and scales move together, so replay never re-quantizes
        differently) and the slot's table is re-pointed at the private
        copy.  Returns ``(cache, n_copied)``.  Device pools are donated
        into the copy dispatch, matching the model entry points.
        """
        if self.refcounts is None or lo_token > hi_token:
            return self, 0
        page = self.page_size
        p_lo = lo_token // page
        p_hi = min(hi_token // page, self._mapped(seq) - 1)
        if p_hi < p_lo:
            return self, 0
        table = (self.page_table_host if self.page_table_host is not None
                 else np.asarray(self.page_table))
        shared = [
            (pi, int(table[seq, pi]))
            for pi in range(p_lo, p_hi + 1)
            if self.refcounts[int(table[seq, pi])] > 1
        ]
        if not shared:
            return self, 0
        if len(shared) > len(self.free):
            raise OutOfPages(
                f"seq {seq}: copy-on-write needs {len(shared)} pages, "
                f"{len(self.free)} free"
            )
        refs = self.refcounts.copy()
        free = list(self.free)
        pt = self._host_table()
        kp, vp = self.k_pages, self.v_pages
        ks, vs = self.k_scale, self.v_scale
        with _donation_noop_ok():
            for pi, src in shared:
                dst = free.pop()
                src_i = np.int32(src)
                dst_i = np.int32(dst)
                kp = _copy_pool_page(kp, src_i, dst_i)
                vp = _copy_pool_page(vp, src_i, dst_i)
                if ks is not None:
                    ks = _copy_pool_page(ks, src_i, dst_i)
                    vs = _copy_pool_page(vs, src_i, dst_i)
                refs[src] -= 1
                refs[dst] = 1
                pt[seq, pi] = dst
        return dataclasses.replace(
            self, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            page_table=jnp.asarray(pt), page_table_host=pt,
            free=free, refcounts=refs,
        ), len(shared)


# ---------------------------------------------------------------------------
# PagedLM: an attention-only LM that decodes straight out of the page pool
# ---------------------------------------------------------------------------


def _paged_lm_decode_step(params, tokens, k_pages, v_pages, k_scale, v_scale,
                          page_table, lengths, active, *, h, kvh, hd, impl):
    """One batched decode step against the paged pool.

    tokens (B,) int32; active (B,) bool — inactive slots write nothing, keep
    length 0 and produce zero attention.  Every array op is row-wise per
    sequence, so slot placement / batch composition never changes a
    sequence's bits.

    ``k_scale``/``v_scale`` are the (L, P, page, KVH) fp32 scale pools of an
    int8 KV pool, or ``None`` in full-precision mode: when given, the append
    quantizes on write (codes + scales through the same indirect burst) and
    attention dequantizes page-by-page in VMEM.

    The per-layer pool updates are collected and stacked once at the end
    (rather than chained through ``k_pages.at[l].set``), so the trace holds
    one full-pool value instead of L intermediates; with the pools donated
    at the jit boundary XLA aliases that single value back into the input
    buffers — an in-place update of the resident pool.
    """
    n_layers = params["wq"].shape[0]
    b = tokens.shape[0]
    quantized = k_scale is not None
    x = jnp.take(params["embed"], tokens, axis=0)          # (B, d)
    new_len = lengths + active.astype(lengths.dtype)
    kps, vps, kss, vss = [], [], [], []
    for l in range(n_layers):
        q = (x @ params["wq"][l]).reshape(b, h, hd)
        kn = (x @ params["wk"][l]).reshape(b, kvh, hd)
        vn = (x @ params["wv"][l]).reshape(b, kvh, hd)
        scales = (dict(k_scale=k_scale[l], v_scale=v_scale[l])
                  if quantized else {})
        out = kops.paged_kv_append(
            k_pages[l], v_pages[l], kn, vn, page_table, lengths, active,
            impl=impl, **scales,
        )
        kp, vp = out[0], out[1]
        ks, vs = (out[3], out[4]) if quantized else (None, None)
        kps.append(kp)
        vps.append(vp)
        kss.append(ks)
        vss.append(vs)
        attn = kops.paged_decode_attention(
            q, kp, vp, page_table, new_len, k_scale=ks, v_scale=vs, impl=impl
        )
        x = x + attn.reshape(b, h * hd) @ params["wo"][l]
    logits = x @ params["embed"].T                          # (B, vocab)
    return (logits, jnp.stack(kps), jnp.stack(vps),
            jnp.stack(kss) if quantized else None,
            jnp.stack(vss) if quantized else None, new_len)


def _paged_lm_decode_steps(params, tokens, k_pages, v_pages, k_scale,
                           v_scale, page_table, lengths, active, *, n, vocab,
                           h, kvh, hd, impl):
    """``n`` fused decode steps with on-device greedy sampling.

    One ``lax.scan`` launch: each step runs the single-step core, argmaxes
    its own logits on device, and feeds the sample back as the next input —
    no logits or lengths ever cross to the host.  The scale pools (int8
    mode) ride the scan carry next to the K/V pools.  Returns the (n, B)
    token matrix, the final feed token (``toks[-1]``, returned from inside
    the graph so chained launches never slice on the host), and the updated
    pools/lengths; bitwise identical to ``n`` sequential
    :func:`_paged_lm_decode_step` calls with host-side argmax.
    """

    def body(carry, _):
        toks, kp, vp, ks, vs, lens = carry
        logits, kp, vp, ks, vs, lens = _paged_lm_decode_step(
            params, toks, kp, vp, ks, vs, page_table, lens, active,
            h=h, kvh=kvh, hd=hd, impl=impl,
        )
        nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
        return (nxt, kp, vp, ks, vs, lens), nxt

    (last, k_pages, v_pages, k_scale, v_scale, lengths), toks = jax.lax.scan(
        body, (tokens, k_pages, v_pages, k_scale, v_scale, lengths), None,
        length=n,
    )
    return toks, last, k_pages, v_pages, k_scale, v_scale, lengths


def _paged_lm_prefill_batch(params, tokens, counts, seqs, starts, k_pages,
                            v_pages, k_scale, v_scale, page_table, lengths,
                            *, h, kvh, hd, page, ctx_pages, impl):
    """Advance every pending sequence by one prompt chunk, in one call.

    tokens (R, C) int32 (row r zero-padded past ``counts[r]``); ``seqs`` maps
    rows to batch slots and ``starts`` gives the absolute position of each
    row's tokens[0].  Rows with ``counts[r] == 0`` are padding and touch
    nothing.

    KV rows are scattered through the chunk-bounded indirect write
    (:func:`repro.kernels.ops.paged_kv_write_chunk` — R·W pages of traffic,
    never the whole pool), and each layer's attention runs through
    :func:`repro.kernels.ops.paged_prefill_attention` over only the leading
    ``ctx_pages`` table entries per sequence (the pages that can hold
    context for this chunk), never the full table row.  Under
    ``impl='pallas'`` the context pages stream HBM→VMEM one at a time with
    an online softmax (no gathered context or dense score tensor); under
    ``impl='ref'`` the dense-einsum oracle runs, masked with a finite
    constant so ``counts == 0`` padding rows can never produce NaN softmax
    outputs that poison the donated pools.  ``k_scale``/``v_scale`` (int8
    mode, or ``None``) make the chunk write quantize-on-write and the
    attention dequantize per context page.  Returns the last *real* token's
    logits per row plus the updated pools.
    """
    n_layers = params["wq"].shape[0]
    r, c = tokens.shape
    quantized = k_scale is not None
    x = jnp.take(params["embed"], tokens, axis=0)          # (R, C, d)
    rows = jnp.take(page_table, seqs, axis=0)              # (R, n_pages)
    ctx_rows = rows[:, :ctx_pages]
    kps, vps, kss, vss = [], [], [], []
    for l in range(n_layers):
        kn = (x @ params["wk"][l]).reshape(r, c, kvh, hd)
        vn = (x @ params["wv"][l]).reshape(r, c, kvh, hd)
        scales = (dict(k_scale=k_scale[l], v_scale=v_scale[l])
                  if quantized else {})
        out = kops.paged_kv_write_chunk(
            k_pages[l], v_pages[l], kn, vn, rows, starts, counts,
            impl=impl, **scales,
        )
        kp, vp = out[0], out[1]
        ks, vs = (out[2], out[3]) if quantized else (None, None)
        kps.append(kp)
        vps.append(vp)
        kss.append(ks)
        vss.append(vs)
        q = (x @ params["wq"][l]).reshape(r, c, h, hd)
        attn = kops.paged_prefill_attention(
            q, kp, vp, ctx_rows, starts, counts, k_scale=ks, v_scale=vs,
            impl=impl,
        )
        x = x + attn.astype(x.dtype).reshape(r, c, h * hd) @ params["wo"][l]
    last = jnp.take_along_axis(
        x, jnp.clip(counts - 1, 0, c - 1)[:, None, None].astype(jnp.int32),
        axis=1,
    )[:, 0]                                                # (R, d)
    # Advance each real row's slot length in-graph (padding rows dropped).
    b = lengths.shape[0]
    new_len = lengths.at[jnp.where(counts > 0, seqs, b)].set(
        (starts + counts).astype(lengths.dtype), mode="drop"
    )
    return (last @ params["embed"].T, jnp.stack(kps), jnp.stack(vps),
            jnp.stack(kss) if quantized else None,
            jnp.stack(vss) if quantized else None, new_len)


class PagedLM:
    """Attention-only LM serving straight out of a :class:`PagedKVCache`.

    Deliberately minimal (tied embeddings, no norms/MLP, greedy-friendly
    float32 math): every per-token computation is row-wise, so a sequence's
    outputs depend only on its own tokens and pages — the property the
    scheduler's static-batch equivalence guarantees rest on.  All heavy data
    movement runs through the packed stream ops: ``paged_kv_append`` /
    ``paged_kv_write_chunk`` (the indirect write converters) and
    ``paged_decode_attention`` (the indirect read / scalar-prefetch kernel).

    Every jitted entry point donates the page pools, and the wrappers keep
    the cache's host shadows (``lengths_host``) in step arithmetically, so
    calling code never needs to read device state back.

    ``kv_dtype='int8'`` serves from quantized page pools: K/V rows are
    quantized on write (per-(token, kv-head) scales into the donated scale
    pools) and both attention kernels dequantize page-by-page in VMEM — the
    serving analogue of packing narrower elements onto a fixed-width bus
    (packing factor ``bus/elem``: 8-bit elements quadruple the FP32 factor).
    The matching cache must be created with the same ``kv_dtype``.
    """

    #: Max resident jitted prefill programs.  Each distinct ``(page, ctx)``
    #: bucket mints one program; ragged prompt-length traffic over many page
    #: sizes would otherwise grow the cache without bound.
    PREFILL_CACHE_CAP = 8

    def __init__(self, cfg: ArchConfig, key: jax.Array, impl: str = "pallas",
                 prefill_cache_cap: Optional[int] = None,
                 kv_dtype: Optional[str] = None):
        self.cfg = cfg
        self.impl = impl
        self.kv_dtype = (
            PagedKVCache.KV_DTYPES[kv_dtype] if kv_dtype is not None
            else cfg.compute_dtype
        )
        h, kvh = cfg.heads_for_tp(1)
        self.h, self.kvh, self.hd = h, kvh, cfg.hd
        d, L = cfg.d_model, cfg.n_layers
        self.prefill_cache_cap = (
            self.PREFILL_CACHE_CAP if prefill_cache_cap is None
            else prefill_cache_cap
        )
        # LRU over (page, ctx_pages) buckets: refreshed on hit, evicted
        # oldest-first past the cap (a re-requested evicted bucket simply
        # re-jits — correctness never depends on residency).
        self._prefill_cache: "collections.OrderedDict[Tuple[int, int], Any]" \
            = collections.OrderedDict()
        ks = jax.random.split(key, 5)
        init = lambda k, *s: (jax.random.normal(k, s, jnp.float32)
                              / np.sqrt(s[-2]))
        self.params = {
            "embed": jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32) * 0.02,
            "wq": init(ks[1], L, d, h * cfg.hd),
            "wk": init(ks[2], L, d, kvh * cfg.hd),
            "wv": init(ks[3], L, d, kvh * cfg.hd),
            "wo": init(ks[4], L, h * cfg.hd, d),
        }

    @functools.cached_property
    def _decode(self):
        return jax.jit(functools.partial(
            _paged_lm_decode_step, h=self.h, kvh=self.kvh, hd=self.hd,
            impl=self.impl,
        ), donate_argnums=(2, 3, 4, 5))

    @functools.cached_property
    def _decode_many(self):
        return jax.jit(functools.partial(
            _paged_lm_decode_steps, vocab=self.cfg.vocab, h=self.h,
            kvh=self.kvh, hd=self.hd, impl=self.impl,
        ), static_argnames=("n",), donate_argnums=(2, 3, 4, 5))

    def _prefill(self, page: int, ctx_pages: int):
        return jax.jit(functools.partial(
            _paged_lm_prefill_batch, h=self.h, kvh=self.kvh, hd=self.hd,
            page=page, ctx_pages=ctx_pages, impl=self.impl,
        ), donate_argnums=(5, 6, 7, 8))

    @property
    def quantized(self) -> bool:
        return self.kv_dtype == jnp.int8

    @functools.cached_property
    def kv_token_bytes(self) -> int:
        """FP32-equivalent bytes per live KV token (K+V, all layers).

        This is the *full-width* footprint — what a packing-oblivious BASE
        server streams per token regardless of the pool's element width.
        The packed width is derived from it via :attr:`kv_elem_bits` and
        :attr:`kv_scale_token_bytes` (see
        ``repro.core.packing.packed_token_bytes``).
        """
        return 2 * self.cfg.n_layers * self.kvh * self.hd * 4

    @functools.cached_property
    def kv_elem_bits(self) -> int:
        """Element width of the KV pools on the stream (32/16/8 bits)."""
        return jnp.dtype(self.kv_dtype).itemsize * 8

    @functools.cached_property
    def kv_scale_token_bytes(self) -> int:
        """Sideband scale bytes PACK moves per live KV token (int8 mode).

        One fp32 scale per (token, kv-head) per pool per layer; zero in
        full-precision modes.
        """
        return 2 * self.cfg.n_layers * self.kvh * 4 if self.quantized else 0

    # -- decode --------------------------------------------------------------

    def _shift_lengths(self, cache: PagedKVCache, active, steps: int):
        if cache.lengths_host is None:
            return None
        return (cache.lengths_host
                + steps * np.asarray(active).astype(np.int32))

    def decode_step(self, tokens, cache: PagedKVCache, active):
        """One decode step; returns (logits, cache).  Pools are donated —
        the passed-in cache's device arrays must not be reused."""
        act_host = np.asarray(active)
        with _donation_noop_ok():
            logits, kp, vp, ks, vs, new_len = self._decode(
                self.params, jnp.asarray(tokens), cache.k_pages,
                cache.v_pages, cache.k_scale, cache.v_scale,
                cache.page_table, cache.lengths,
                jnp.asarray(active),
            )
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=new_len,
            lengths_host=self._shift_lengths(cache, act_host, 1),
        )
        return logits, cache

    def decode_steps(self, tokens, cache: PagedKVCache, active, n: int):
        """``n`` fused decode steps with device-side greedy sampling.

        Returns (tokens (n, B) — a *device* array, synced only when the
        caller reads it — and the updated cache).  Bitwise equivalent to
        ``n`` sequential ``decode_step`` + host argmax iterations.
        """
        act_host = np.asarray(active)
        with _donation_noop_ok():
            toks, _, kp, vp, ks, vs, new_len = self._decode_many(
                self.params, jnp.asarray(tokens), cache.k_pages,
                cache.v_pages, cache.k_scale, cache.v_scale,
                cache.page_table, cache.lengths,
                jnp.asarray(active), n=n,
            )
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=new_len,
            lengths_host=self._shift_lengths(cache, act_host, n),
        )
        return toks, cache

    def decode_upto(self, tokens, cache: PagedKVCache, active, n: int):
        """Fused decode of exactly ``n`` steps as a chain of pow2 scans.

        Power-of-two scan lengths keep the jit cache to O(log n) entries
        while the feed token, pools, and lengths stay on device between
        chunks; the (n, B) token matrix crosses to the host exactly once,
        here.  Returns (tokens (n, B) np.ndarray, cache).
        """
        act_host = np.asarray(active)
        act_dev = jnp.asarray(active)
        feed = jnp.asarray(tokens)
        kp, vp = cache.k_pages, cache.v_pages
        ks, vs = cache.k_scale, cache.v_scale
        lens = cache.lengths
        parts = []
        rem = n
        with _donation_noop_ok():
            while rem:
                m = 1 << (rem.bit_length() - 1)
                toks, feed, kp, vp, ks, vs, lens = self._decode_many(
                    self.params, feed, kp, vp, ks, vs, cache.page_table,
                    lens, act_dev, n=m,
                )
                parts.append(toks)
                rem -= m
        out = np.concatenate([np.asarray(t) for t in parts], axis=0)  # sync
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=lens,
            lengths_host=self._shift_lengths(cache, act_host, n),
        )
        return out, cache

    # -- prefill -------------------------------------------------------------

    def prefill_batch(self, tokens: np.ndarray, counts: np.ndarray,
                      slots: np.ndarray, starts: np.ndarray,
                      cache: PagedKVCache):
        """Advance all pending sequences by one chunk; returns (logits, cache).

        tokens (R, C) int32; counts/slots/starts (R,) host arrays.  Rows
        with ``counts == 0`` are padding.  The attention context is bounded
        by the mapped pages the furthest row needs, bucketed to the next
        power of two so the jit cache stays small.
        """
        counts = np.asarray(counts, np.int32)
        starts = np.asarray(starts, np.int32)
        slots = np.asarray(slots, np.int32)
        page = cache.page_size
        need = int(max(1, -(-int((starts + counts).max()) // page)))
        ctx = 1
        while ctx < need:
            ctx *= 2
        ctx = min(ctx, cache.pages_per_seq)
        key = (page, ctx)
        fn = self._prefill_cache.get(key)
        if fn is None:
            fn = self._prefill_cache[key] = self._prefill(page, ctx)
            while len(self._prefill_cache) > self.prefill_cache_cap:
                self._prefill_cache.popitem(last=False)
        else:
            self._prefill_cache.move_to_end(key)
        with _donation_noop_ok():
            logits, kp, vp, ks, vs, new_len = fn(
                self.params, jnp.asarray(tokens), jnp.asarray(counts),
                jnp.asarray(slots), jnp.asarray(starts),
                cache.k_pages, cache.v_pages, cache.k_scale, cache.v_scale,
                cache.page_table, cache.lengths,
            )
        real = counts > 0
        lens_host = cache.lengths_host
        if lens_host is not None:
            lens_host = lens_host.copy()
            lens_host[slots[real]] = (starts + counts)[real]
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=new_len, lengths_host=lens_host,
        )
        return logits, cache

    def prefill_chunk(self, tokens, count: int, seq: int, start: int,
                      cache: PagedKVCache):
        """Single-sequence chunked prefill (the R=1 row of the batched path)."""
        logits, cache = self.prefill_batch(
            np.asarray(tokens, np.int32)[None, :],
            np.asarray([count], np.int32),
            np.asarray([seq], np.int32),
            np.asarray([start], np.int32),
            cache,
        )
        return logits[0], cache


class ServeEngine:
    """Minimal production-shaped engine: prefill, batched greedy decode."""

    def __init__(self, cfg: ArchConfig, params, rules: ShardingRules,
                 max_len: int = 512, batch: int = 8, impl: str = "pallas"):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.max_len = max_len
        self.impl = impl
        self.cache = lm.init_cache(cfg, batch, max_len)
        self._prefill = jax.jit(
            lambda p, b, c: lm.prefill(p, b, c, cfg, rules)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, rules)
        )

    def generate(
        self, prompts: jax.Array, n_new: int, greedy: bool = True,
        rng: Optional[jax.Array] = None,
    ) -> np.ndarray:
        """prompts (B, S0) int32 → (B, n_new) generated ids."""
        b, s0 = prompts.shape
        logits, self.cache = self._prefill(
            self.params, {"tokens": prompts}, self.cache
        )
        out = []
        tok = self._sample(logits[:, 0], greedy, rng, 0)
        for i in range(n_new):
            out.append(tok)
            logits, self.cache = self._decode(
                self.params, tok[:, None], self.cache, s0 + i
            )
            tok = self._sample(logits, greedy, rng, i + 1)
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits, greedy, rng, step):
        logits = logits[..., : self.cfg.vocab]  # drop TP padding classes
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(rng, step)
        return jax.random.categorical(key, logits).astype(jnp.int32)
