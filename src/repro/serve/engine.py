"""Serving engine: batched prefill + decode with contiguous or paged KV.

Two cache strategies:

* **Contiguous** (``lm.init_cache``) — what the dry-run decode cells lower:
  cache sequence sharded over 'model' (flash-decoding SP).
* **Paged** (this module) — fixed-size pages + per-sequence page tables; the
  page table is the AXI-Pack indirect stream descriptor and decode attention
  runs through the ``paged_decode_attention`` kernel (scalar-prefetched page
  ids → direct HBM page DMAs).  Used by examples/serve_decode.py and the
  batching tests; pages admit continuous batching (sequences of different
  lengths enter/leave without reshaping the pool).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.packing import pack_indirect, unpack_indirect
from repro.kernels import ops as kops
from repro.models import lm
from repro.models.common import rms_norm
from repro.parallel.sharding import ShardingRules


class OutOfPages(RuntimeError):
    """Raised when a page allocation cannot be satisfied from the free pool."""


@dataclasses.dataclass
class PagedKVCache:
    """Physical page pool + per-sequence page tables (one per layer stack).

    ``free`` and ``mapped`` are *host-side* bookkeeping shared across the
    functional ``dataclasses.replace`` copies: ``allocate``/``release`` mutate
    them in place while returning a new dataclass with the updated device
    arrays, so mid-flight sequence entry/exit (continuous batching) never
    reshapes the pool.
    """

    k_pages: jax.Array     # (L, P, page, KVH, hd)
    v_pages: jax.Array
    page_table: jax.Array  # (B, n_pages) physical ids
    lengths: jax.Array     # (B,)
    free: List[int]
    mapped: Optional[np.ndarray] = None  # (B,) pages currently mapped per slot

    @classmethod
    def create(cls, cfg: ArchConfig, batch: int, max_len: int, page: int = 64,
               tp: int = 1, pool_pages: Optional[int] = None):
        q_heads, kv_heads = cfg.heads_for_tp(tp)
        n_pages_seq = max_len // page
        pool = pool_pages if pool_pages is not None else batch * n_pages_seq
        dt = cfg.compute_dtype
        return cls(
            k_pages=jnp.zeros((cfg.n_layers, pool, page, kv_heads, cfg.hd), dt),
            v_pages=jnp.zeros((cfg.n_layers, pool, page, kv_heads, cfg.hd), dt),
            page_table=jnp.zeros((batch, n_pages_seq), jnp.int32),
            lengths=jnp.zeros((batch,), jnp.int32),
            free=list(range(pool)),
            mapped=np.zeros((batch,), np.int64),
        )

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def pages_per_seq(self) -> int:
        return self.page_table.shape[1]

    @property
    def total_pages(self) -> int:
        return self.k_pages.shape[1]

    @property
    def n_free(self) -> int:
        return len(self.free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _mapped(self, seq: int) -> int:
        if self.mapped is not None:
            return int(self.mapped[seq])
        ln = int(np.asarray(self.lengths)[seq])
        return self.pages_for(ln)

    def allocate(self, seq: int, n_pages: int) -> "PagedKVCache":
        """Map ``n_pages`` new physical pages after the slot's current ones."""
        if n_pages > len(self.free):
            raise OutOfPages(
                f"seq {seq} needs {n_pages} pages, {len(self.free)} free"
            )
        start = self._mapped(seq)
        if start + n_pages > self.pages_per_seq:
            raise OutOfPages(
                f"seq {seq}: {start}+{n_pages} pages exceeds the "
                f"{self.pages_per_seq}-page table row"
            )
        ids = [self.free.pop() for _ in range(n_pages)]
        pt = np.array(self.page_table)  # writable host copy
        pt[seq, start:start + n_pages] = ids
        if self.mapped is not None:
            self.mapped[seq] = start + n_pages
        return dataclasses.replace(self, page_table=jnp.asarray(pt))

    def release(self, seq: int) -> "PagedKVCache":
        """Return a slot's pages to the pool (sequence exit / eviction)."""
        pt = np.array(self.page_table)
        used = self._mapped(seq)
        self.free.extend(int(p) for p in pt[seq, :used])
        pt[seq, :] = 0
        lengths = np.array(self.lengths)
        lengths[seq] = 0
        if self.mapped is not None:
            self.mapped[seq] = 0
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), lengths=jnp.asarray(lengths)
        )


# ---------------------------------------------------------------------------
# PagedLM: an attention-only LM that decodes straight out of the page pool
# ---------------------------------------------------------------------------


def _paged_lm_decode_step(params, tokens, k_pages, v_pages, page_table,
                          lengths, active, *, h, kvh, hd, impl):
    """One batched decode step against the paged pool.

    tokens (B,) int32; active (B,) bool — inactive slots write nothing, keep
    length 0 and produce zero attention.  Every array op is row-wise per
    sequence, so slot placement / batch composition never changes a
    sequence's bits.
    """
    n_layers = params["wq"].shape[0]
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)          # (B, d)
    new_len = lengths + active.astype(lengths.dtype)
    for l in range(n_layers):
        q = (x @ params["wq"][l]).reshape(b, h, hd)
        kn = (x @ params["wk"][l]).reshape(b, kvh, hd)
        vn = (x @ params["wv"][l]).reshape(b, kvh, hd)
        kp, vp, _ = kops.paged_kv_append(
            k_pages[l], v_pages[l], kn, vn, page_table, lengths, active,
            impl=impl,
        )
        k_pages = k_pages.at[l].set(kp)
        v_pages = v_pages.at[l].set(vp)
        attn = kops.paged_decode_attention(
            q, kp, vp, page_table, new_len, impl=impl
        )
        x = x + attn.reshape(b, h * hd) @ params["wo"][l]
    logits = x @ params["embed"].T                          # (B, vocab)
    return logits, k_pages, v_pages, new_len


def _paged_lm_prefill_chunk(params, tokens, count, seq, start, k_pages,
                            v_pages, page_table, *, h, kvh, hd, page, impl):
    """Process one fixed-size prompt chunk of one sequence.

    tokens (C,) int32 (zero-padded past ``count``); ``start`` is the absolute
    position of tokens[0].  KV rows are scattered into the pool through the
    packed indirect write (:func:`repro.core.packing.unpack_indirect`), then
    each layer's attention gathers the sequence's full table row
    (:func:`repro.core.packing.pack_indirect`) — fixed shapes, so chunked
    prefill is bitwise independent of scheduling interleave.  Returns the
    last *real* token's logits plus the updated pools.
    """
    n_layers = params["wq"].shape[0]
    c = tokens.shape[0]
    p_tot = k_pages.shape[1]
    n_pages = page_table.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0)          # (C, d)
    row = jnp.take(page_table, seq, axis=0)                # (n_pages,)
    pos = start + jnp.arange(c, dtype=jnp.int32)
    valid = jnp.arange(c, dtype=jnp.int32) < count
    flat_idx = jnp.take(row, pos // page) * page + pos % page
    flat_idx = jnp.where(valid, flat_idx, p_tot * page)    # OOB → dropped
    kv_pos = jnp.arange(n_pages * page, dtype=jnp.int32)
    causal = kv_pos[None, :] <= pos[:, None]               # (C, S)
    scale = 1.0 / np.sqrt(hd)
    rep = h // kvh
    for l in range(n_layers):
        kn = (x @ params["wk"][l]).reshape(c, kvh, hd)
        vn = (x @ params["wv"][l]).reshape(c, kvh, hd)
        kp = unpack_indirect(
            k_pages[l].reshape(p_tot * page, kvh, hd), kn, flat_idx
        ).reshape(p_tot, page, kvh, hd)
        vp = unpack_indirect(
            v_pages[l].reshape(p_tot * page, kvh, hd), vn, flat_idx
        ).reshape(p_tot, page, kvh, hd)
        k_pages = k_pages.at[l].set(kp)
        v_pages = v_pages.at[l].set(vp)
        # Indirect read of the sequence's logical KV: (n_pages, page, KVH, hd)
        kg = pack_indirect(kp, row).reshape(n_pages * page, kvh, hd)
        vg = pack_indirect(vp, row).reshape(n_pages * page, kvh, hd)
        kg = jnp.repeat(kg, rep, axis=1)                   # (S, h, hd)
        vg = jnp.repeat(vg, rep, axis=1)
        q = (x @ params["wq"][l]).reshape(c, h, hd)
        s = jnp.einsum("chd,shd->chs", q, kg).astype(jnp.float32) * scale
        s = jnp.where(causal[:, None, :], s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("chs,shd->chd", w, vg.astype(jnp.float32))
        x = x + attn.astype(x.dtype).reshape(c, h * hd) @ params["wo"][l]
    x_last = jax.lax.dynamic_index_in_dim(x, count - 1, 0, keepdims=False)
    return x_last @ params["embed"].T, k_pages, v_pages


class PagedLM:
    """Attention-only LM serving straight out of a :class:`PagedKVCache`.

    Deliberately minimal (tied embeddings, no norms/MLP, greedy-friendly
    float32 math): every per-token computation is row-wise, so a sequence's
    outputs depend only on its own tokens and pages — the property the
    scheduler's static-batch equivalence guarantees rest on.  All heavy data
    movement runs through the packed stream ops: ``paged_kv_append`` (the
    indirect write converter) and ``paged_decode_attention`` (the indirect
    read / scalar-prefetch kernel).
    """

    def __init__(self, cfg: ArchConfig, key: jax.Array, impl: str = "pallas"):
        self.cfg = cfg
        self.impl = impl
        h, kvh = cfg.heads_for_tp(1)
        self.h, self.kvh, self.hd = h, kvh, cfg.hd
        d, L = cfg.d_model, cfg.n_layers
        self._prefill_cache: Dict[int, Any] = {}
        ks = jax.random.split(key, 5)
        init = lambda k, *s: (jax.random.normal(k, s, jnp.float32)
                              / np.sqrt(s[-2]))
        self.params = {
            "embed": jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32) * 0.02,
            "wq": init(ks[1], L, d, h * cfg.hd),
            "wk": init(ks[2], L, d, kvh * cfg.hd),
            "wv": init(ks[3], L, d, kvh * cfg.hd),
            "wo": init(ks[4], L, h * cfg.hd, d),
        }

    @functools.cached_property
    def _decode(self):
        return jax.jit(functools.partial(
            _paged_lm_decode_step, h=self.h, kvh=self.kvh, hd=self.hd,
            impl=self.impl,
        ))

    def _prefill(self, page: int):
        return jax.jit(functools.partial(
            _paged_lm_prefill_chunk, h=self.h, kvh=self.kvh, hd=self.hd,
            page=page, impl=self.impl,
        ))

    @functools.cached_property
    def kv_token_bytes(self) -> int:
        """Bytes a decode step reads per live KV token (K+V, all layers)."""
        return 2 * self.cfg.n_layers * self.kvh * self.hd * 4

    def decode_step(self, tokens, cache: PagedKVCache, active):
        logits, kp, vp, new_len = self._decode(
            self.params, tokens, cache.k_pages, cache.v_pages,
            cache.page_table, cache.lengths, active,
        )
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, lengths=new_len
        )
        return logits, cache

    def prefill_chunk(self, tokens, count: int, seq: int, start: int,
                      cache: PagedKVCache):
        fn = self._prefill_cache.get(cache.page_size)
        if fn is None:
            fn = self._prefill_cache[cache.page_size] = self._prefill(
                cache.page_size
            )
        logits, kp, vp = fn(
            self.params, tokens, jnp.int32(count), jnp.int32(seq),
            jnp.int32(start), cache.k_pages, cache.v_pages, cache.page_table,
        )
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp,
            lengths=cache.lengths.at[seq].set(start + count),
        )
        return logits, cache


class ServeEngine:
    """Minimal production-shaped engine: prefill, batched greedy decode."""

    def __init__(self, cfg: ArchConfig, params, rules: ShardingRules,
                 max_len: int = 512, batch: int = 8, impl: str = "pallas"):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.max_len = max_len
        self.impl = impl
        self.cache = lm.init_cache(cfg, batch, max_len)
        self._prefill = jax.jit(
            lambda p, b, c: lm.prefill(p, b, c, cfg, rules)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, rules)
        )

    def generate(
        self, prompts: jax.Array, n_new: int, greedy: bool = True,
        rng: Optional[jax.Array] = None,
    ) -> np.ndarray:
        """prompts (B, S0) int32 → (B, n_new) generated ids."""
        b, s0 = prompts.shape
        logits, self.cache = self._prefill(
            self.params, {"tokens": prompts}, self.cache
        )
        out = []
        tok = self._sample(logits[:, 0], greedy, rng, 0)
        for i in range(n_new):
            out.append(tok)
            logits, self.cache = self._decode(
                self.params, tok[:, None], self.cache, s0 + i
            )
            tok = self._sample(logits, greedy, rng, i + 1)
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits, greedy, rng, step):
        logits = logits[..., : self.cfg.vocab]  # drop TP padding classes
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(rng, step)
        return jax.random.categorical(key, logits).astype(jnp.int32)
