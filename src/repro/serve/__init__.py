"""Serving: prefill/decode engine, paged KV pool, continuous batching,
SLA-aware admission/preemption, and the chaos/fault-injection layer."""
from .engine import OutOfPages, PagedKVCache, PagedLM, ServeEngine
from .faults import (
    FaultPlan,
    InvariantViolation,
    check_scheduler_invariants,
    terminal_states,
)
from .scheduler import (
    TERMINAL_STATES,
    PrefixIndex,
    RejectReason,
    Request,
    RequestRejected,
    RequestState,
    Scheduler,
    SchedulerStalledError,
    ServeStats,
    StepRecord,
    build_prefill_rows,
    static_batch_generate,
)
