"""Serving: prefill/decode engine, contiguous + paged KV caches."""
from .engine import PagedKVCache, ServeEngine
