"""Serving: prefill/decode engine, paged KV pool, continuous batching."""
from .engine import OutOfPages, PagedKVCache, PagedLM, ServeEngine
from .scheduler import (
    PrefixIndex,
    Request,
    RequestState,
    Scheduler,
    ServeStats,
    StepRecord,
    build_prefill_rows,
    static_batch_generate,
)
