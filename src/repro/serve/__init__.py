"""Serving: model families (paged transformer, recurrent RWKV6/Mamba),
the family protocol, continuous batching with SLA-aware admission and
preemption, and the chaos/fault-injection layer.

Layering: ``family`` defines the :class:`ServableFamily` protocol the
scheduler speaks; ``kv`` owns the paged KV pool; ``paged_lm`` binds the
transformer engine to it; ``recurrent_lm`` serves fixed-size-state models
out of donated state pools; ``scheduler`` drives any family; ``faults``
injects chaos and checks invariants — family-agnostically.
"""
from .drafter import Drafter, NGramDrafter, TinyLMDrafter
from .family import OutOfPages, ServableFamily
from .kv import PagedKVCache
from .paged_lm import PagedFamily, PagedLM, static_batch_generate
from .recurrent_lm import (
    RecurrentFamily,
    RecurrentLM,
    RecurrentStatePool,
    recurrent_reference_generate,
)
from .faults import (
    FaultPlan,
    InvariantViolation,
    check_scheduler_invariants,
    terminal_states,
)
from .scheduler import (
    TERMINAL_STATES,
    PrefixIndex,
    RejectReason,
    Request,
    RequestRejected,
    RequestState,
    Scheduler,
    SchedulerStalledError,
    ServeStats,
    StepRecord,
    build_prefill_rows,
)
