"""The ``ServableFamily`` protocol: one scheduler, many model families.

The scheduler (``repro.serve.scheduler``) implements continuous batching,
SLA-aware admission, eviction with bit-for-bit replay, prefix sharing, and
chaos degradation — none of which is specific to transformers.  What *is*
family-specific is how a sequence's serving state lives in device memory
and what bus traffic touching it costs:

* **Paged attention** (``repro.serve.paged_lm.PagedFamily``): KV state
  grows one token per decode step, lives in fixed-size pages, and every
  access is an *indirect* burst — the page table is the memory-resident
  index vector of the AXI-Pack gather.
* **Recurrent state** (``repro.serve.recurrent_lm.RecurrentFamily``):
  RWKV6/Mamba state is fixed-size per sequence — the degenerate
  "single page that never grows" — and every decode step is a *strided*
  read-modify-write over the (layer, slot) state pool.

The protocol speaks in **resource units** so both map onto the same
admission/eviction arithmetic: a unit is a page for the paged family
(``units_for(n)`` = pages covering ``n`` tokens) and a state slot for
recurrent families (``units_for(n)`` = 1 for any non-empty sequence —
allocated at admission, never grown).  Eviction is identical in both:
``release`` returns the units, and re-admission replays by re-prefill —
``replay(slot)`` resets whatever per-slot state a fresh prefill assumes
(zeroed recurrent state; a no-op for paged families, whose fresh pages
are empty by construction).

The scheduler holds exactly one ``ServableFamily`` and calls nothing
else — no ``isinstance(PagedLM)``, no KV-specific attribute.  Traffic
accounting is part of the protocol (``step_streams`` /
``prefill_account``) so each family reports its own stream dialect:
:class:`repro.core.streams.IndirectStream` page walks for paged KV,
:class:`repro.core.streams.StridedStream` state walks for recurrent
state — and ``BENCH_serving.json`` can compare the two on equal terms.
"""
from __future__ import annotations

import abc
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.packing import Traffic

__all__ = ["OutOfPages", "ServableFamily"]


class OutOfPages(RuntimeError):
    """Raised when a resource-unit allocation cannot be satisfied.

    Historically "pages" (the paged KV pool); recurrent families raise it
    when the state pool has no free slot.  The scheduler treats it as
    back-pressure, never as a crash.
    """


class ServableFamily(abc.ABC):
    """Everything the scheduler needs from one servable model family.

    A family binds a model to its resource pool (page pool or state pool)
    and owns all device state; the scheduler only does bookkeeping in
    resource units and records the (Traffic, stream) accounts the family
    hands back.  Implementations: ``PagedFamily`` (``serve/paged_lm.py``)
    and ``RecurrentFamily`` (``serve/recurrent_lm.py``).
    """

    #: Short family label for stats/benchmark rows (e.g. "paged", "rwkv6").
    name: str = "family"

    # -- geometry -----------------------------------------------------------

    @property
    @abc.abstractmethod
    def batch(self) -> int:
        """Number of batch slots (concurrent residents)."""

    @property
    @abc.abstractmethod
    def vocab(self) -> int:
        """Real vocabulary size (sampling never sees padding classes)."""

    @property
    @abc.abstractmethod
    def total_units(self) -> int:
        """Pool capacity in resource units (pages / state slots)."""

    @property
    @abc.abstractmethod
    def free_units(self) -> int:
        """Unallocated resource units right now."""

    @property
    @abc.abstractmethod
    def slot_token_capacity(self) -> int:
        """Max prompt+generation tokens one slot can ever hold."""

    @property
    def page_size(self) -> int:
        """Tokens per unit when units are token-granular (sharing/table
        math); 0 for families whose units are whole-sequence state."""
        return 0

    @property
    @abc.abstractmethod
    def pool_bytes(self) -> int:
        """Device bytes held by the family's resource pool."""

    @abc.abstractmethod
    def units_for(self, n_tokens: int) -> int:
        """Resource units a sequence of ``n_tokens`` occupies."""

    @abc.abstractmethod
    def mapped_units(self, slot: int) -> int:
        """Units currently allocated to ``slot``."""

    @abc.abstractmethod
    def token_capacity(self, slot: int) -> int:
        """Tokens ``slot`` can hold before it must ``grow`` again."""

    @abc.abstractmethod
    def state_bytes(self, n_tokens: int) -> int:
        """Full-width device bytes of serving state for ``n_tokens`` live
        tokens — what a packing-oblivious BASE server streams per touch.
        Linear in ``n_tokens`` for paged KV; constant for recurrent
        state."""

    @abc.abstractmethod
    def lengths(self) -> np.ndarray:
        """Host shadow of per-slot token counts (no device sync)."""

    # -- lifecycle ----------------------------------------------------------

    @abc.abstractmethod
    def alloc_state(self, slot: int, units: int) -> None:
        """Allocate ``units`` more units to ``slot``; raise
        :class:`OutOfPages` (nothing committed) when the pool is short."""

    def grow(self, slot: int, units: int) -> bool:
        """Decode-time growth: like ``alloc_state`` but returns ``False``
        instead of raising, so the scheduler can defer the slot a step."""
        try:
            self.alloc_state(slot, units)
            return True
        except OutOfPages:
            return False

    def trim(self, slot: int, keep_units: int) -> None:
        """Return units beyond ``keep_units`` that hold no live state
        (lookahead reclaim).  Families whose units are never
        over-provisioned may no-op."""

    @abc.abstractmethod
    def release(self, slot: int) -> None:
        """Drop every unit ``slot`` holds (retirement or eviction)."""

    def replay(self, slot: int) -> None:
        """Reset ``slot`` to the state a fresh prefill assumes, so
        re-prefill after eviction rebuilds bit-for-bit.  Called at every
        admission (a fresh prompt is the degenerate zero-token replay).
        Paged families no-op — freshly allocated pages hold no live KV;
        recurrent families zero the slot's state rows."""

    # -- model compute ------------------------------------------------------

    @abc.abstractmethod
    def prefill_batch(self, tokens: np.ndarray, counts: np.ndarray,
                      slots: np.ndarray, starts: np.ndarray):
        """Advance every pending row by one prompt chunk in one launch.

        Same row contract as ``build_prefill_rows``: ``tokens`` (R, C)
        int32, rows with ``counts == 0`` are padding.  Returns the last
        real token's logits per row as a *device* array — the scheduler
        syncs it only at admission boundaries."""

    @abc.abstractmethod
    def decode_steps(self, tokens: np.ndarray, active: np.ndarray,
                     n: int) -> np.ndarray:
        """``n`` fused greedy decode steps; returns the (n, B) host token
        matrix (one sync at the boundary).  Must be bitwise identical to
        ``n`` single steps — the replay guarantee rests on it."""

    # -- speculative decoding (optional) ------------------------------------

    @property
    def spec_k(self) -> int:
        """Speculative verify width: tokens scored per sequence per verify
        launch step.  1 (the default) means the family decodes plainly and
        the scheduler never calls the verify methods below — families
        without a speculative path need to change nothing."""
        return 1

    def verify_steps(self, tokens: np.ndarray, active: np.ndarray,
                     n: int) -> Tuple[np.ndarray, np.ndarray]:
        """``n`` fused draft→verify→accept steps over ``active`` slots.

        Returns ``(toks (n, B, spec_k), counts (n, B))`` host arrays: step
        ``s`` emitted ``counts[s, b]`` tokens for slot ``b``, namely
        ``toks[s, b, :counts[s, b]]`` (one device sync at the boundary).
        Emitted tokens must be bitwise the plain greedy decode sequence —
        the replay guarantee extends to speculation unchanged."""
        raise NotImplementedError(f"{self.name}: no speculative decoding")

    def verify_account(self, lens0: np.ndarray, active: np.ndarray,
                       counts: np.ndarray) -> List[Tuple[Traffic, tuple]]:
        """Per-step (Traffic, stream descriptors) for a verify run that
        just completed — called *after* ``verify_steps`` with the
        pre-launch length shadow ``lens0`` and the emitted ``counts``,
        since speculative context lengths are data-dependent."""
        raise NotImplementedError(f"{self.name}: no speculative decoding")

    # -- traffic accounting -------------------------------------------------

    @abc.abstractmethod
    def step_streams(self, active: np.ndarray,
                     n: int) -> List[Tuple[Traffic, tuple]]:
        """Per-step (Traffic, stream descriptors) for the ``n`` decode
        steps about to run on ``active`` slots.  Called immediately
        before ``decode_steps``; derived from host shadows only."""

    @abc.abstractmethod
    def prefill_account(self, slots: np.ndarray, starts: np.ndarray,
                        counts: np.ndarray) -> Tuple[Traffic, tuple]:
        """(Traffic, stream descriptors) for the prefill chunk that just
        ran over these rows."""

    # -- prefix sharing capability (optional) -------------------------------

    @property
    def supports_prefix_sharing(self) -> bool:
        """Whether units are token-granular and refcounted (paged pools
        with refcounts).  Everything below may raise when this is
        False — the scheduler never calls it then."""
        return False

    def share(self, slot: int, unit_ids: List[int]) -> None:
        raise NotImplementedError(f"{self.name}: no prefix sharing")

    def retain_units(self, unit_ids: List[int]) -> None:
        raise NotImplementedError(f"{self.name}: no prefix sharing")

    def release_units(self, unit_ids: List[int]) -> None:
        raise NotImplementedError(f"{self.name}: no prefix sharing")

    def unit_refcount(self, unit_id: int) -> int:
        raise NotImplementedError(f"{self.name}: no prefix sharing")

    def slot_unit_ids(self, slot: int) -> List[int]:
        raise NotImplementedError(f"{self.name}: no prefix sharing")

    def ensure_writable(self, slot: int, lo_token: int,
                        hi_token: int) -> int:
        """Copy-on-write any shared unit covering [lo, hi]; returns the
        number of copies.  Default: nothing is ever shared, 0 copies."""
        return 0

    def share_account(self, shared_tokens: int,
                      unit_ids: Sequence[int]) -> Tuple[Traffic, tuple]:
        raise NotImplementedError(f"{self.name}: no prefix sharing")

    # -- invariants ---------------------------------------------------------

    @abc.abstractmethod
    def check_integrity(self, retained: int = 0) -> None:
        """Assert the pool's host bookkeeping is self-consistent
        (free/owned partition, refcount conservation with ``retained``
        out-of-table owners, shadow consistency).  Raises
        ``AssertionError`` on the first violation; the chaos suite calls
        this after every scheduler step."""
