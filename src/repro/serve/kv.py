"""Paged KV pool: fixed-size physical pages + per-sequence page tables.

Split out of the old ``serve/engine.py``: this module owns only the pool
data structure and its host bookkeeping — the transformer engine that
serves from it lives in ``repro.serve.paged_lm``.

The page table is the AXI-Pack indirect stream descriptor: decode
attention resolves it on device (scalar-prefetched page ids → direct HBM
page DMAs) while the scheduler does all allocation/refcount bookkeeping
against the host shadows, never syncing device state on the hot path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from .family import OutOfPages

__all__ = ["OutOfPages", "PagedKVCache"]


@contextlib.contextmanager
def _donation_noop_ok():
    """Silence jax's donation-unusable warning for one library dispatch.

    Pool donation is a deliberate no-op on CPU backends and the fast path is
    identical either way, so the warning is noise *for these calls only* —
    the suppression is scoped with ``catch_warnings`` so user code's own
    donation diagnostics (where a failed donation is a real memory bug) are
    never swallowed."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pool_page(pool: jax.Array, src, dst) -> jax.Array:
    """``pool[:, dst] = pool[:, src]`` across all layers, in place.

    ``src``/``dst`` are traced scalars, so every copy-on-write page copy
    reuses one compiled program per pool shape/dtype; donation lets XLA
    alias the update into the resident pool instead of cloning it.
    """
    return pool.at[:, dst].set(pool[:, src])


@dataclasses.dataclass
class PagedKVCache:
    """Physical page pool + per-sequence page tables (one per layer stack).

    The dataclass is *functional*: ``allocate``/``release`` copy every piece
    of host bookkeeping they touch before writing (``free``, ``mapped``,
    ``lengths_host``, ``page_table_host``, ``refcounts``) and return a new
    cache, so a retained older cache object is never corrupted by later
    calls.  (Exception: :meth:`ensure_writable` dispatches device page
    copies with the pools donated, matching the contract of every jitted
    model entry point — after calling it, the old cache's device arrays
    must not be reused.)

    ``refcounts`` makes pages shareable: each physical page counts its
    owners (page-table mappings plus prefix-index retentions) and is
    returned to ``free`` only when the count hits zero.  ``share`` maps
    another sequence's pages by refcount bump, ``ensure_writable`` performs
    copy-on-write before a shared page is written, and
    ``retain_pages``/``release_pages`` hold pages alive for a prompt-prefix
    index without any slot mapping them.

    ``lengths_host``/``page_table_host`` are host-side shadows of the device
    arrays, maintained by :class:`repro.serve.paged_lm.PagedLM` and
    ``allocate``/``release``; the scheduler reads them instead of syncing
    device state on the hot path.

    ``kv_dtype='int8'`` allocates int8 K/V pools plus fp32 *scale pools*
    (``k_scale``/``v_scale``, shape (L, P, page, KVH) — one scale per page
    token slot per KV head, the layout of ``ref.quantize_kv``).  The scale
    pools are donated alongside the K/V pools in every jitted entry point,
    and page bookkeeping (allocate/trim/release) needs no extra work: a
    physical page owns its scale rows, so remapping the page remaps its
    scales — eviction/replay rebuilds both bit-for-bit through the same
    quantize-on-write ops.
    """

    k_pages: jax.Array     # (L, P, page, KVH, hd) — int8 codes in int8 mode
    v_pages: jax.Array
    page_table: jax.Array  # (B, n_pages) physical ids
    lengths: jax.Array     # (B,)
    free: List[int]
    mapped: Optional[np.ndarray] = None  # (B,) pages currently mapped per slot
    lengths_host: Optional[np.ndarray] = None      # (B,) int32 shadow
    page_table_host: Optional[np.ndarray] = None   # (B, n_pages) int32 shadow
    k_scale: Optional[jax.Array] = None  # (L, P, page, KVH) fp32, int8 mode
    v_scale: Optional[jax.Array] = None
    refcounts: Optional[np.ndarray] = None  # (P,) owners per physical page

    #: kv_dtype name → pool dtype (None = the config's compute dtype).
    KV_DTYPES = {
        "fp32": jnp.float32, "float32": jnp.float32,
        "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
        "int8": jnp.int8,
    }

    @classmethod
    def create(cls, cfg: ArchConfig, batch: int, max_len: int, page: int = 64,
               tp: int = 1, pool_pages: Optional[int] = None,
               kv_dtype=None):
        """``kv_dtype`` is a name from :attr:`KV_DTYPES`, an actual dtype
        (e.g. a :class:`repro.serve.paged_lm.PagedLM`'s ``kv_dtype``,
        guaranteeing model/cache agreement), or ``None`` for the config's
        compute dtype."""
        q_heads, kv_heads = cfg.heads_for_tp(tp)
        n_pages_seq = max_len // page
        pool = pool_pages if pool_pages is not None else batch * n_pages_seq
        if kv_dtype is None:
            dt = cfg.compute_dtype
        elif isinstance(kv_dtype, str):
            dt = cls.KV_DTYPES[kv_dtype]
        else:
            dt = jnp.dtype(kv_dtype).type
        shape = (cfg.n_layers, pool, page, kv_heads, cfg.hd)
        quantized = dt == jnp.int8
        # Scale init of 1.0 matches ref.int8_quantize on all-zero rows, so an
        # unwritten page dequantizes to exact zeros either way.
        return cls(
            k_pages=jnp.zeros(shape, dt),
            v_pages=jnp.zeros(shape, dt),
            page_table=jnp.zeros((batch, n_pages_seq), jnp.int32),
            lengths=jnp.zeros((batch,), jnp.int32),
            free=list(range(pool)),
            mapped=np.zeros((batch,), np.int64),
            lengths_host=np.zeros((batch,), np.int32),
            page_table_host=np.zeros((batch, n_pages_seq), np.int32),
            k_scale=jnp.ones(shape[:-1], jnp.float32) if quantized else None,
            v_scale=jnp.ones(shape[:-1], jnp.float32) if quantized else None,
            refcounts=np.zeros((pool,), np.int64),
        )

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def pool_bytes(self) -> int:
        """Device bytes held by the K/V pools (scale pools included)."""
        total = self.k_pages.nbytes + self.v_pages.nbytes
        if self.quantized:
            total += self.k_scale.nbytes + self.v_scale.nbytes
        return total

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def pages_per_seq(self) -> int:
        return self.page_table.shape[1]

    @property
    def total_pages(self) -> int:
        return self.k_pages.shape[1]

    @property
    def n_free(self) -> int:
        return len(self.free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _mapped(self, seq: int) -> int:
        if self.mapped is not None:
            return int(self.mapped[seq])
        if self.lengths_host is not None:
            return self.pages_for(int(self.lengths_host[seq]))
        ln = int(np.asarray(self.lengths)[seq])
        return self.pages_for(ln)

    def _host_table(self) -> np.ndarray:
        if self.page_table_host is not None:
            return np.array(self.page_table_host)
        return np.array(self.page_table)

    def _drop_ref(self, refs: Optional[np.ndarray], free: List[int],
                  page: int) -> None:
        """Drop one owner of ``page``; free it when no owners remain.

        With no refcount array (legacy caches built before sharing) every
        page has exactly one owner and the drop is an immediate free.
        """
        if refs is None:
            free.append(page)
            return
        refs[page] -= 1
        if refs[page] < 0:
            raise AssertionError(f"page {page} refcount went negative")
        if refs[page] == 0:
            free.append(page)

    def allocate(self, seq: int, n_pages: int) -> "PagedKVCache":
        """Map ``n_pages`` new physical pages after the slot's current ones."""
        if n_pages > len(self.free):
            raise OutOfPages(
                f"seq {seq} needs {n_pages} pages, {len(self.free)} free"
            )
        start = self._mapped(seq)
        if start + n_pages > self.pages_per_seq:
            raise OutOfPages(
                f"seq {seq}: {start}+{n_pages} pages exceeds the "
                f"{self.pages_per_seq}-page table row"
            )
        free = list(self.free)
        ids = [free.pop() for _ in range(n_pages)]
        refs = None if self.refcounts is None else self.refcounts.copy()
        if refs is not None:
            for p in ids:
                refs[p] = 1
        pt = self._host_table()
        pt[seq, start:start + n_pages] = ids
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = start + n_pages
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            free=free, mapped=mapped, refcounts=refs,
        )

    def trim(self, seq: int, keep_pages: int) -> "PagedKVCache":
        """Unmap a slot's pages beyond ``keep_pages``.

        Only meaningful for pages past the written content (lookahead
        over-provisioning): trimmed pages hold no live KV *for this slot*,
        so remapping them later on demand is loss-free.  A trimmed page
        still referenced elsewhere (a prefix sibling or the prefix index)
        is only un-mapped here — it returns to the free pool when its last
        owner drops it.
        """
        used = self._mapped(seq)
        if keep_pages >= used:
            return self
        pt = self._host_table()
        free = list(self.free)
        refs = None if self.refcounts is None else self.refcounts.copy()
        for p in pt[seq, keep_pages:used]:
            self._drop_ref(refs, free, int(p))
        pt[seq, keep_pages:used] = 0
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = keep_pages
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            free=free, mapped=mapped, refcounts=refs,
        )

    def release(self, seq: int) -> "PagedKVCache":
        """Drop a slot's page mappings (sequence exit / eviction).

        Each page loses this slot as an owner; pages with no remaining
        owners return to the free pool.
        """
        pt = self._host_table()
        used = self._mapped(seq)
        free = list(self.free)
        refs = None if self.refcounts is None else self.refcounts.copy()
        for p in pt[seq, :used]:
            self._drop_ref(refs, free, int(p))
        pt[seq, :] = 0
        if self.lengths_host is not None:
            lengths = self.lengths_host.copy()
        else:
            lengths = np.array(self.lengths)
        lengths[seq] = 0
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = 0
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            lengths=jnp.asarray(lengths),
            lengths_host=lengths if self.lengths_host is not None else None,
            free=free, mapped=mapped, refcounts=refs,
        )

    # -- prefix sharing ------------------------------------------------------

    def share(self, seq: int, page_ids: List[int]) -> "PagedKVCache":
        """Map already-populated physical pages into ``seq`` by refcount bump.

        The pages' KV contents are untouched — the new sequence reads the
        prefix another sequence prefilled.  Writes into a shared page must
        go through :meth:`ensure_writable` first.
        """
        if not page_ids:
            return self
        if self.refcounts is None:
            raise ValueError("share() requires a refcounted cache")
        start = self._mapped(seq)
        if start + len(page_ids) > self.pages_per_seq:
            raise OutOfPages(
                f"seq {seq}: {start}+{len(page_ids)} shared pages exceeds "
                f"the {self.pages_per_seq}-page table row"
            )
        refs = self.refcounts.copy()
        for p in page_ids:
            if refs[p] <= 0:
                raise AssertionError(f"cannot share unowned page {p}")
            refs[p] += 1
        pt = self._host_table()
        pt[seq, start:start + len(page_ids)] = page_ids
        mapped = None if self.mapped is None else self.mapped.copy()
        if mapped is not None:
            mapped[seq] = start + len(page_ids)
        return dataclasses.replace(
            self, page_table=jnp.asarray(pt), page_table_host=pt,
            mapped=mapped, refcounts=refs,
        )

    def retain_pages(self, page_ids: List[int]) -> "PagedKVCache":
        """Add one owner to each page (prefix-index retention)."""
        if not page_ids:
            return self
        if self.refcounts is None:
            raise ValueError("retain_pages() requires a refcounted cache")
        refs = self.refcounts.copy()
        for p in page_ids:
            if refs[p] <= 0:
                raise AssertionError(f"cannot retain unowned page {p}")
            refs[p] += 1
        return dataclasses.replace(self, refcounts=refs)

    def release_pages(self, page_ids: List[int]) -> "PagedKVCache":
        """Drop one owner from each page; zero-owner pages return to free."""
        if not page_ids:
            return self
        if self.refcounts is None:
            raise ValueError("release_pages() requires a refcounted cache")
        refs = self.refcounts.copy()
        free = list(self.free)
        for p in page_ids:
            self._drop_ref(refs, free, int(p))
        return dataclasses.replace(self, refcounts=refs, free=free)

    def check_integrity(self, retained: int = 0) -> None:
        """Assert the pool's host-side bookkeeping is self-consistent.

        ``retained`` is the number of out-of-table owners (prefix-index
        retentions) the refcount conservation law must account for.  Checks
        — all host-side, no device sync:

        * the free list holds no duplicates and only valid page ids;
        * no page is simultaneously free and owned, and free + owned
          partition the pool (refcounted caches);
        * conservation: ``refcounts.sum() == mapped.sum() + retained``;
        * every mapped page-table entry points at an owned page, and
          entries beyond ``mapped`` are zeroed (no orphaned host shadows);
        * ``lengths_host`` never exceeds the mapped capacity of its slot.

        Raises ``AssertionError`` on the first violation; the chaos suite
        (``repro.serve.faults``) calls this after every scheduler step.
        """
        free = list(self.free)
        assert len(free) == len(set(free)), "duplicate pages in free list"
        assert all(0 <= p < self.total_pages for p in free), \
            f"free list holds out-of-range page: {free}"
        refs = self.refcounts
        table = self.page_table_host
        if refs is not None:
            assert (refs >= 0).all(), "negative refcount"
            owned = {p for p in range(self.total_pages) if refs[p] > 0}
            overlap = owned & set(free)
            assert not overlap, f"pages both free and owned: {sorted(overlap)}"
            assert len(owned) + len(free) == self.total_pages, (
                f"free ({len(free)}) + owned ({len(owned)}) pages do not "
                f"partition the {self.total_pages}-page pool"
            )
            if self.mapped is not None:
                assert int(refs.sum()) == int(self.mapped.sum()) + retained, (
                    f"refcount conservation broken: refs {int(refs.sum())} "
                    f"!= mapped {int(self.mapped.sum())} + retained {retained}"
                )
        if table is not None and self.mapped is not None:
            for seq in range(table.shape[0]):
                used = int(self.mapped[seq])
                for p in table[seq, :used]:
                    assert int(p) not in set(free), \
                        f"seq {seq} maps free page {int(p)}"
                    if refs is not None:
                        assert refs[int(p)] >= 1, \
                            f"seq {seq} maps unowned page {int(p)}"
                assert not table[seq, used:].any(), (
                    f"seq {seq}: orphaned table entries beyond its "
                    f"{used} mapped pages"
                )
                if self.lengths_host is not None:
                    ln = int(self.lengths_host[seq])
                    assert ln <= used * self.page_size, (
                        f"seq {seq}: length shadow {ln} exceeds "
                        f"{used} mapped pages"
                    )

    def ensure_writable(self, seq: int, lo_token: int,
                        hi_token: int) -> Tuple["PagedKVCache", int]:
        """Copy-on-write any shared page covering tokens [lo, hi] of ``seq``.

        Pages in the token range with more than one owner are copied to
        fresh physical pages (K/V pools and, in int8 mode, the scale pools
        — the codes and scales move together, so replay never re-quantizes
        differently) and the slot's table is re-pointed at the private
        copy.  Returns ``(cache, n_copied)``.  Device pools are donated
        into the copy dispatch, matching the model entry points.
        """
        if self.refcounts is None or lo_token > hi_token:
            return self, 0
        page = self.page_size
        p_lo = lo_token // page
        p_hi = min(hi_token // page, self._mapped(seq) - 1)
        if p_hi < p_lo:
            return self, 0
        table = (self.page_table_host if self.page_table_host is not None
                 else np.asarray(self.page_table))
        shared = [
            (pi, int(table[seq, pi]))
            for pi in range(p_lo, p_hi + 1)
            if self.refcounts[int(table[seq, pi])] > 1
        ]
        if not shared:
            return self, 0
        if len(shared) > len(self.free):
            raise OutOfPages(
                f"seq {seq}: copy-on-write needs {len(shared)} pages, "
                f"{len(self.free)} free"
            )
        refs = self.refcounts.copy()
        free = list(self.free)
        pt = self._host_table()
        kp, vp = self.k_pages, self.v_pages
        ks, vs = self.k_scale, self.v_scale
        with _donation_noop_ok():
            for pi, src in shared:
                dst = free.pop()
                src_i = np.int32(src)
                dst_i = np.int32(dst)
                kp = _copy_pool_page(kp, src_i, dst_i)
                vp = _copy_pool_page(vp, src_i, dst_i)
                if ks is not None:
                    ks = _copy_pool_page(ks, src_i, dst_i)
                    vs = _copy_pool_page(vs, src_i, dst_i)
                refs[src] -= 1
                refs[dst] = 1
                pt[seq, pi] = dst
        return dataclasses.replace(
            self, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            page_table=jnp.asarray(pt), page_table_host=pt,
            free=free, refcounts=refs,
        ), len(shared)
