"""Paged transformer engine: an attention-only LM over the page pool.

Split out of the old ``serve/engine.py`` next to ``serve/kv.py`` (the pool)
and ``serve/family.py`` (the scheduler protocol).  This module owns the
jitted prefill/decode programs, the :class:`PagedLM` wrapper that keeps the
cache's host shadows in step, and :class:`PagedFamily` — the
:class:`repro.serve.family.ServableFamily` implementation the scheduler
drives (resource units = pages, streams = indirect page walks).

The paged path is built as a *device-resident fast path*: the page pools are
donated into every jitted call (``donate_argnums``) so they update in place
instead of being copied per step, greedy sampling happens on device, and
``decode_steps`` fuses ``n`` decode iterations into one ``lax.scan`` launch
that feeds its own samples back — the host only sees tokens when the
scheduler reaches a scheduling boundary (admission, page growth,
retirement).  Host-side shadow state (``lengths_host``/``page_table_host``)
lets all bookkeeping and traffic accounting run without a single
device→host sync on the hot path.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.packing import (
    Traffic,
    paged_decode_traffic,
    paged_prefill_traffic,
    prefix_share_traffic,
    spec_verify_traffic,
)
from repro.core.streams import (
    page_table_streams,
    prefill_table_streams,
    share_table_streams,
    verify_table_streams,
)
from repro.kernels import ops as kops
from .drafter import Drafter, NGramDrafter
from .family import ServableFamily
from .kv import PagedKVCache, _donation_noop_ok

__all__ = ["PagedFamily", "PagedLM", "static_batch_generate"]


def _paged_lm_decode_step(params, tokens, k_pages, v_pages, k_scale, v_scale,
                          page_table, lengths, active, *, h, kvh, hd, impl):
    """One batched decode step against the paged pool.

    tokens (B,) int32; active (B,) bool — inactive slots write nothing, keep
    length 0 and produce zero attention.  Every array op is row-wise per
    sequence, so slot placement / batch composition never changes a
    sequence's bits.

    ``k_scale``/``v_scale`` are the (L, P, page, KVH) fp32 scale pools of an
    int8 KV pool, or ``None`` in full-precision mode: when given, the append
    quantizes on write (codes + scales through the same indirect burst) and
    attention dequantizes page-by-page in VMEM.

    The per-layer pool updates are collected and stacked once at the end
    (rather than chained through ``k_pages.at[l].set``), so the trace holds
    one full-pool value instead of L intermediates; with the pools donated
    at the jit boundary XLA aliases that single value back into the input
    buffers — an in-place update of the resident pool.
    """
    n_layers = params["wq"].shape[0]
    b = tokens.shape[0]
    quantized = k_scale is not None
    x = jnp.take(params["embed"], tokens, axis=0)          # (B, d)
    new_len = lengths + active.astype(lengths.dtype)
    kps, vps, kss, vss = [], [], [], []
    for l in range(n_layers):
        q = (x @ params["wq"][l]).reshape(b, h, hd)
        kn = (x @ params["wk"][l]).reshape(b, kvh, hd)
        vn = (x @ params["wv"][l]).reshape(b, kvh, hd)
        scales = (dict(k_scale=k_scale[l], v_scale=v_scale[l])
                  if quantized else {})
        out = kops.paged_kv_append(
            k_pages[l], v_pages[l], kn, vn, page_table, lengths, active,
            impl=impl, **scales,
        )
        kp, vp = out[0], out[1]
        ks, vs = (out[3], out[4]) if quantized else (None, None)
        kps.append(kp)
        vps.append(vp)
        kss.append(ks)
        vss.append(vs)
        attn = kops.paged_decode_attention(
            q, kp, vp, page_table, new_len, k_scale=ks, v_scale=vs, impl=impl
        )
        x = x + attn.reshape(b, h * hd) @ params["wo"][l]
    logits = x @ params["embed"].T                          # (B, vocab)
    return (logits, jnp.stack(kps), jnp.stack(vps),
            jnp.stack(kss) if quantized else None,
            jnp.stack(vss) if quantized else None, new_len)


def _paged_lm_decode_steps(params, tokens, k_pages, v_pages, k_scale,
                           v_scale, page_table, lengths, active, *, n, vocab,
                           h, kvh, hd, impl):
    """``n`` fused decode steps with on-device greedy sampling.

    One ``lax.scan`` launch: each step runs the single-step core, argmaxes
    its own logits on device, and feeds the sample back as the next input —
    no logits or lengths ever cross to the host.  The scale pools (int8
    mode) ride the scan carry next to the K/V pools.  Returns the (n, B)
    token matrix, the final feed token (``toks[-1]``, returned from inside
    the graph so chained launches never slice on the host), and the updated
    pools/lengths; bitwise identical to ``n`` sequential
    :func:`_paged_lm_decode_step` calls with host-side argmax.
    """

    def body(carry, _):
        toks, kp, vp, ks, vs, lens = carry
        logits, kp, vp, ks, vs, lens = _paged_lm_decode_step(
            params, toks, kp, vp, ks, vs, page_table, lens, active,
            h=h, kvh=kvh, hd=hd, impl=impl,
        )
        nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
        return (nxt, kp, vp, ks, vs, lens), nxt

    (last, k_pages, v_pages, k_scale, v_scale, lengths), toks = jax.lax.scan(
        body, (tokens, k_pages, v_pages, k_scale, v_scale, lengths), None,
        length=n,
    )
    return toks, last, k_pages, v_pages, k_scale, v_scale, lengths


def _paged_lm_prefill_batch(params, tokens, counts, seqs, starts, k_pages,
                            v_pages, k_scale, v_scale, page_table, lengths,
                            *, h, kvh, hd, page, ctx_pages, impl):
    """Advance every pending sequence by one prompt chunk, in one call.

    tokens (R, C) int32 (row r zero-padded past ``counts[r]``); ``seqs`` maps
    rows to batch slots and ``starts`` gives the absolute position of each
    row's tokens[0].  Rows with ``counts[r] == 0`` are padding and touch
    nothing.

    KV rows are scattered through the chunk-bounded indirect write
    (:func:`repro.kernels.ops.paged_kv_write_chunk` — R·W pages of traffic,
    never the whole pool), and each layer's attention runs through
    :func:`repro.kernels.ops.paged_prefill_attention` over only the leading
    ``ctx_pages`` table entries per sequence (the pages that can hold
    context for this chunk), never the full table row.  Under
    ``impl='pallas'`` the context pages stream HBM→VMEM one at a time with
    an online softmax (no gathered context or dense score tensor); under
    ``impl='ref'`` the dense-einsum oracle runs, masked with a finite
    constant so ``counts == 0`` padding rows can never produce NaN softmax
    outputs that poison the donated pools.  ``k_scale``/``v_scale`` (int8
    mode, or ``None``) make the chunk write quantize-on-write and the
    attention dequantize per context page.  Returns the last *real* token's
    logits per row plus the updated pools.
    """
    n_layers = params["wq"].shape[0]
    r, c = tokens.shape
    quantized = k_scale is not None
    x = jnp.take(params["embed"], tokens, axis=0)          # (R, C, d)
    rows = jnp.take(page_table, seqs, axis=0)              # (R, n_pages)
    ctx_rows = rows[:, :ctx_pages]
    kps, vps, kss, vss = [], [], [], []
    for l in range(n_layers):
        kn = (x @ params["wk"][l]).reshape(r, c, kvh, hd)
        vn = (x @ params["wv"][l]).reshape(r, c, kvh, hd)
        scales = (dict(k_scale=k_scale[l], v_scale=v_scale[l])
                  if quantized else {})
        out = kops.paged_kv_write_chunk(
            k_pages[l], v_pages[l], kn, vn, rows, starts, counts,
            impl=impl, **scales,
        )
        kp, vp = out[0], out[1]
        ks, vs = (out[2], out[3]) if quantized else (None, None)
        kps.append(kp)
        vps.append(vp)
        kss.append(ks)
        vss.append(vs)
        q = (x @ params["wq"][l]).reshape(r, c, h, hd)
        attn = kops.paged_prefill_attention(
            q, kp, vp, ctx_rows, starts, counts, k_scale=ks, v_scale=vs,
            impl=impl,
        )
        x = x + attn.astype(x.dtype).reshape(r, c, h * hd) @ params["wo"][l]
    last = jnp.take_along_axis(
        x, jnp.clip(counts - 1, 0, c - 1)[:, None, None].astype(jnp.int32),
        axis=1,
    )[:, 0]                                                # (R, d)
    # Advance each real row's slot length in-graph (padding rows dropped).
    b = lengths.shape[0]
    new_len = lengths.at[jnp.where(counts > 0, seqs, b)].set(
        (starts + counts).astype(lengths.dtype), mode="drop"
    )
    return (last @ params["embed"].T, jnp.stack(kps), jnp.stack(vps),
            jnp.stack(kss) if quantized else None,
            jnp.stack(vss) if quantized else None, new_len)


def _paged_lm_verify_step(params, q_tokens, k_pages, v_pages, k_scale,
                          v_scale, page_table, lengths, counts, *, h, kvh,
                          hd, ctx_pages, impl):
    """Score K speculative tokens per sequence in one multi-query pass.

    q_tokens (B, K) int32 — the feed token at column 0, draft tokens after
    it; row ``b``'s token ``i`` sits at absolute position
    ``lengths[b] + i``.  ``counts`` (B,) bounds the valid tokens per row
    (0..K; 0 = inactive row, touches nothing).

    Structurally one prefill-chunk pass with ``starts = lengths``: each
    layer scatters the chunk's K/V through the chunk-bounded indirect
    write, then :func:`repro.kernels.ops.paged_verify` scores all K causal
    queries in **one** clamped page walk — the walk plain decode would
    repeat K times.  Returns per-position logits (B, K, vocab) plus the
    updated pools; lengths are *not* advanced here (the accept step owns
    that, since only accepted tokens survive).
    """
    n_layers = params["wq"].shape[0]
    b, k = q_tokens.shape
    quantized = k_scale is not None
    x = jnp.take(params["embed"], q_tokens, axis=0)        # (B, K, d)
    ctx_rows = page_table[:, :ctx_pages]
    kps, vps, kss, vss = [], [], [], []
    for l in range(n_layers):
        kn = (x @ params["wk"][l]).reshape(b, k, kvh, hd)
        vn = (x @ params["wv"][l]).reshape(b, k, kvh, hd)
        scales = (dict(k_scale=k_scale[l], v_scale=v_scale[l])
                  if quantized else {})
        out = kops.paged_kv_write_chunk(
            k_pages[l], v_pages[l], kn, vn, page_table, lengths, counts,
            impl=impl, **scales,
        )
        kp, vp = out[0], out[1]
        ks, vs = (out[2], out[3]) if quantized else (None, None)
        kps.append(kp)
        vps.append(vp)
        kss.append(ks)
        vss.append(vs)
        q = (x @ params["wq"][l]).reshape(b, k, h, hd)
        attn = kops.paged_verify(
            q, kp, vp, ctx_rows, lengths, counts, k_scale=ks, v_scale=vs,
            impl=impl,
        )
        x = x + attn.astype(x.dtype).reshape(b, k, h * hd) @ params["wo"][l]
    logits = x @ params["embed"].T                          # (B, K, vocab)
    return (logits, jnp.stack(kps), jnp.stack(vps),
            jnp.stack(kss) if quantized else None,
            jnp.stack(vss) if quantized else None)


def _paged_lm_verify_steps(params, feed, dstate, k_pages, v_pages, k_scale,
                           v_scale, page_table, lengths, active, caps, *,
                           drafter, n, spec_k, vocab, h, kvh, hd, ctx_pages,
                           impl):
    """``n`` fused draft→verify→accept iterations in one ``lax.scan``.

    The speculative hot loop, entirely on device: each iteration drafts
    ``spec_k - 1`` tokens from the drafter state, scores feed+drafts with
    :func:`_paged_lm_verify_step`, greedy-accepts the matched prefix plus
    the model's bonus token (:func:`repro.kernels.ops.speculative_accept`),
    advances lengths by the emitted count (the KV *rollback*: rejected
    appends past the first mismatch are simply left behind the new length,
    masked out of every later attention and overwritten by the next
    iteration's chunk write), and folds the outcome into the drafter
    state.  The host sees nothing until the caller syncs the stacked
    (n, B, K) token / (n, B) count outputs at the launch boundary.

    ``caps`` (B,) is each slot's mapped-token capacity: per iteration the
    scored count is clamped in-graph to ``min(spec_k, caps - lengths)``
    so speculation can never write past a slot's mapped pages —
    capacity-starved slots degrade towards fewer scored tokens (0 = the
    slot stalls until the scheduler grows it).

    Emitted tokens are the target model's argmax only — bitwise the plain
    greedy decode sequence regardless of drafts or drafter state (wrong
    drafts cost acceptance rate, never bits).
    """

    def body(carry, _):
        fd, ds, kp, vp, ks, vs, lens = carry
        drafts = drafter.draft(ds, fd, spec_k - 1)          # (B, K-1)
        q_tokens = jnp.concatenate(
            [fd[:, None], drafts.astype(jnp.int32)], axis=1
        )
        counts = jnp.where(
            active, jnp.clip(caps - lens, 0, spec_k), 0
        ).astype(jnp.int32)
        logits, kp, vp, ks, vs = _paged_lm_verify_step(
            params, q_tokens, kp, vp, ks, vs, page_table, lens, counts,
            h=h, kvh=kvh, hd=hd, ctx_pages=ctx_pages, impl=impl,
        )
        g = jnp.argmax(logits[..., :vocab], axis=-1).astype(jnp.int32)
        n_emit = kops.speculative_accept(drafts, g, counts)
        fd = jnp.where(
            n_emit > 0,
            jnp.take_along_axis(
                g, jnp.clip(n_emit - 1, 0, spec_k - 1)[:, None], axis=1
            )[:, 0],
            fd,
        )
        ds = drafter.update(ds, q_tokens, g, n_emit)
        lens = lens + n_emit.astype(lens.dtype)
        return (fd, ds, kp, vp, ks, vs, lens), (g, n_emit)

    carry = (feed, dstate, k_pages, v_pages, k_scale, v_scale, lengths)
    (feed, dstate, k_pages, v_pages, k_scale, v_scale, lengths), \
        (toks, counts) = jax.lax.scan(body, carry, None, length=n)
    return (toks, counts, feed, dstate, k_pages, v_pages, k_scale, v_scale,
            lengths)


class PagedLM:
    """Attention-only LM serving straight out of a :class:`PagedKVCache`.

    Deliberately minimal (tied embeddings, no norms/MLP, greedy-friendly
    float32 math): every per-token computation is row-wise, so a sequence's
    outputs depend only on its own tokens and pages — the property the
    scheduler's static-batch equivalence guarantees rest on.  All heavy data
    movement runs through the packed stream ops: ``paged_kv_append`` /
    ``paged_kv_write_chunk`` (the indirect write converters) and
    ``paged_decode_attention`` (the indirect read / scalar-prefetch kernel).

    Every jitted entry point donates the page pools, and the wrappers keep
    the cache's host shadows (``lengths_host``) in step arithmetically, so
    calling code never needs to read device state back.

    ``kv_dtype='int8'`` serves from quantized page pools: K/V rows are
    quantized on write (per-(token, kv-head) scales into the donated scale
    pools) and both attention kernels dequantize page-by-page in VMEM — the
    serving analogue of packing narrower elements onto a fixed-width bus
    (packing factor ``bus/elem``: 8-bit elements quadruple the FP32 factor).
    The matching cache must be created with the same ``kv_dtype``.
    """

    #: Max resident jitted prefill *and* verify programs (one shared LRU).
    #: Each distinct ``(page, ctx)`` prefill bucket or
    #: ``("verify", spec_k, page, ctx)`` verify bucket mints one program;
    #: ragged prompt-length traffic over many page sizes would otherwise
    #: grow the cache without bound.
    PREFILL_CACHE_CAP = 8

    def __init__(self, cfg: ArchConfig, key: jax.Array, impl: str = "pallas",
                 prefill_cache_cap: Optional[int] = None,
                 kv_dtype: Optional[str] = None, spec_k: int = 1,
                 drafter: Optional[Drafter] = None):
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.cfg = cfg
        self.impl = impl
        self.spec_k = spec_k
        self.drafter = drafter if drafter is not None else NGramDrafter(
            cfg.vocab
        )
        self.kv_dtype = (
            PagedKVCache.KV_DTYPES[kv_dtype] if kv_dtype is not None
            else cfg.compute_dtype
        )
        h, kvh = cfg.heads_for_tp(1)
        self.h, self.kvh, self.hd = h, kvh, cfg.hd
        d, L = cfg.d_model, cfg.n_layers
        self.prefill_cache_cap = (
            self.PREFILL_CACHE_CAP if prefill_cache_cap is None
            else prefill_cache_cap
        )
        # LRU over (page, ctx_pages) buckets: refreshed on hit, evicted
        # oldest-first past the cap (a re-requested evicted bucket simply
        # re-jits — correctness never depends on residency).
        self._prefill_cache: "collections.OrderedDict[Tuple[int, int], Any]" \
            = collections.OrderedDict()
        ks = jax.random.split(key, 5)
        init = lambda k, *s: (jax.random.normal(k, s, jnp.float32)
                              / np.sqrt(s[-2]))
        self.params = {
            "embed": jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32) * 0.02,
            "wq": init(ks[1], L, d, h * cfg.hd),
            "wk": init(ks[2], L, d, kvh * cfg.hd),
            "wv": init(ks[3], L, d, kvh * cfg.hd),
            "wo": init(ks[4], L, h * cfg.hd, d),
        }

    def bind(self, cache: PagedKVCache) -> "PagedFamily":
        """Wrap this model + ``cache`` as the scheduler-facing family."""
        return PagedFamily(self, cache)

    @functools.cached_property
    def _decode(self):
        return jax.jit(functools.partial(
            _paged_lm_decode_step, h=self.h, kvh=self.kvh, hd=self.hd,
            impl=self.impl,
        ), donate_argnums=(2, 3, 4, 5))

    @functools.cached_property
    def _decode_many(self):
        return jax.jit(functools.partial(
            _paged_lm_decode_steps, vocab=self.cfg.vocab, h=self.h,
            kvh=self.kvh, hd=self.hd, impl=self.impl,
        ), static_argnames=("n",), donate_argnums=(2, 3, 4, 5))

    def _prefill(self, page: int, ctx_pages: int):
        return jax.jit(functools.partial(
            _paged_lm_prefill_batch, h=self.h, kvh=self.kvh, hd=self.hd,
            page=page, ctx_pages=ctx_pages, impl=self.impl,
        ), donate_argnums=(5, 6, 7, 8))

    def _verify(self, spec_k: int, ctx_pages: int):
        return jax.jit(functools.partial(
            _paged_lm_verify_steps, drafter=self.drafter, spec_k=spec_k,
            vocab=self.cfg.vocab, h=self.h, kvh=self.kvh, hd=self.hd,
            ctx_pages=ctx_pages, impl=self.impl,
        ), static_argnames=("n",), donate_argnums=(3, 4, 5, 6))

    def _cached_program(self, key, make):
        """Shared LRU over jitted prefill *and* verify programs: refreshed
        on hit, evicted oldest-first past the cap (an evicted bucket
        transparently re-jits — correctness never depends on residency)."""
        fn = self._prefill_cache.get(key)
        if fn is None:
            fn = self._prefill_cache[key] = make()
            while len(self._prefill_cache) > self.prefill_cache_cap:
                self._prefill_cache.popitem(last=False)
        else:
            self._prefill_cache.move_to_end(key)
        return fn

    @property
    def quantized(self) -> bool:
        return self.kv_dtype == jnp.int8

    @functools.cached_property
    def kv_token_bytes(self) -> int:
        """FP32-equivalent bytes per live KV token (K+V, all layers).

        This is the *full-width* footprint — what a packing-oblivious BASE
        server streams per token regardless of the pool's element width.
        The packed width is derived from it via :attr:`kv_elem_bits` and
        :attr:`kv_scale_token_bytes` (see
        ``repro.core.packing.packed_token_bytes``).
        """
        return 2 * self.cfg.n_layers * self.kvh * self.hd * 4

    @functools.cached_property
    def kv_elem_bits(self) -> int:
        """Element width of the KV pools on the stream (32/16/8 bits)."""
        return jnp.dtype(self.kv_dtype).itemsize * 8

    @functools.cached_property
    def kv_scale_token_bytes(self) -> int:
        """Sideband scale bytes PACK moves per live KV token (int8 mode).

        One fp32 scale per (token, kv-head) per pool per layer; zero in
        full-precision modes.
        """
        return 2 * self.cfg.n_layers * self.kvh * 4 if self.quantized else 0

    # -- decode --------------------------------------------------------------

    def _shift_lengths(self, cache: PagedKVCache, active, steps: int):
        if cache.lengths_host is None:
            return None
        return (cache.lengths_host
                + steps * np.asarray(active).astype(np.int32))

    def decode_step(self, tokens, cache: PagedKVCache, active):
        """One decode step; returns (logits, cache).  Pools are donated —
        the passed-in cache's device arrays must not be reused."""
        act_host = np.asarray(active)
        with _donation_noop_ok():
            logits, kp, vp, ks, vs, new_len = self._decode(
                self.params, jnp.asarray(tokens), cache.k_pages,
                cache.v_pages, cache.k_scale, cache.v_scale,
                cache.page_table, cache.lengths,
                jnp.asarray(active),
            )
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=new_len,
            lengths_host=self._shift_lengths(cache, act_host, 1),
        )
        return logits, cache

    def decode_steps(self, tokens, cache: PagedKVCache, active, n: int):
        """``n`` fused decode steps with device-side greedy sampling.

        Returns (tokens (n, B) — a *device* array, synced only when the
        caller reads it — and the updated cache).  Bitwise equivalent to
        ``n`` sequential ``decode_step`` + host argmax iterations.
        """
        act_host = np.asarray(active)
        with _donation_noop_ok():
            toks, _, kp, vp, ks, vs, new_len = self._decode_many(
                self.params, jnp.asarray(tokens), cache.k_pages,
                cache.v_pages, cache.k_scale, cache.v_scale,
                cache.page_table, cache.lengths,
                jnp.asarray(active), n=n,
            )
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=new_len,
            lengths_host=self._shift_lengths(cache, act_host, n),
        )
        return toks, cache

    def decode_upto(self, tokens, cache: PagedKVCache, active, n: int):
        """Fused decode of exactly ``n`` steps as a chain of pow2 scans.

        Power-of-two scan lengths keep the jit cache to O(log n) entries
        while the feed token, pools, and lengths stay on device between
        chunks; the (n, B) token matrix crosses to the host exactly once,
        here.  Returns (tokens (n, B) np.ndarray, cache).
        """
        act_host = np.asarray(active)
        act_dev = jnp.asarray(active)
        feed = jnp.asarray(tokens)
        kp, vp = cache.k_pages, cache.v_pages
        ks, vs = cache.k_scale, cache.v_scale
        lens = cache.lengths
        parts = []
        rem = n
        with _donation_noop_ok():
            while rem:
                m = 1 << (rem.bit_length() - 1)
                toks, feed, kp, vp, ks, vs, lens = self._decode_many(
                    self.params, feed, kp, vp, ks, vs, cache.page_table,
                    lens, act_dev, n=m,
                )
                parts.append(toks)
                rem -= m
        out = np.concatenate([np.asarray(t) for t in parts], axis=0)  # sync
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=lens,
            lengths_host=self._shift_lengths(cache, act_host, n),
        )
        return out, cache

    # -- speculative verify --------------------------------------------------

    def verify_upto(self, tokens, cache: PagedKVCache, active, n: int,
                    dstate):
        """``n`` fused draft→verify→accept iterations as pow2 scan chains.

        tokens (B,) int32 feed tokens; ``dstate`` is the drafter state
        pytree (see :class:`repro.serve.drafter.Drafter`).  Like
        :meth:`decode_upto`, power-of-two scan lengths bound the jit cache
        to O(log n) compilations per ``("verify", spec_k, page, ctx)``
        bucket while feed/drafter-state/pools/lengths stay on device
        between chunks; the stacked outputs cross to the host exactly
        once, here.

        Returns ``(toks (n, B, K) np.ndarray, counts (n, B) np.ndarray,
        cache, dstate)`` — step ``s`` emitted ``counts[s, b]`` tokens for
        slot ``b``, namely ``toks[s, b, :counts[s, b]]``.  Unlike plain
        decode the per-step advance is data-dependent, so the host
        lengths shadow is reconciled from the synced counts (still one
        sync per launch).
        """
        k = self.spec_k
        b = cache.page_table.shape[0]
        page = cache.page_size
        lens_host = (cache.lengths_host if cache.lengths_host is not None
                     else np.asarray(cache.lengths))
        act_host = np.asarray(active).astype(bool)
        caps = np.array(
            [cache._mapped(s) * page for s in range(b)], np.int64
        )
        # Context bucket: the furthest any slot can reach this launch.
        hi = np.where(
            act_host, np.minimum(lens_host + n * k, caps), lens_host
        )
        need = int(max(1, -(-int(hi.max()) // page)))
        ctx = 1
        while ctx < need:
            ctx *= 2
        ctx = min(ctx, cache.pages_per_seq)
        fn = self._cached_program(
            ("verify", k, page, ctx), lambda: self._verify(k, ctx)
        )
        feed = jnp.asarray(tokens)
        act_dev = jnp.asarray(act_host)
        caps_dev = jnp.asarray(caps, jnp.int32)
        kp, vp = cache.k_pages, cache.v_pages
        ks, vs = cache.k_scale, cache.v_scale
        lens = cache.lengths
        tok_parts, cnt_parts = [], []
        rem = n
        with _donation_noop_ok():
            while rem:
                m = 1 << (rem.bit_length() - 1)
                toks, counts, feed, dstate, kp, vp, ks, vs, lens = fn(
                    self.params, feed, dstate, kp, vp, ks, vs,
                    cache.page_table, lens, act_dev, caps_dev, n=m,
                )
                tok_parts.append(toks)
                cnt_parts.append(counts)
                rem -= m
        toks_h = np.concatenate(
            [np.asarray(t) for t in tok_parts], axis=0
        )                                                   # sync
        counts_h = np.concatenate([np.asarray(c) for c in cnt_parts], axis=0)
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=lens,
            lengths_host=(lens_host + counts_h.sum(axis=0)).astype(
                lens_host.dtype
            ) if cache.lengths_host is not None else None,
        )
        return toks_h, counts_h, cache, dstate

    # -- prefill -------------------------------------------------------------

    def prefill_batch(self, tokens: np.ndarray, counts: np.ndarray,
                      slots: np.ndarray, starts: np.ndarray,
                      cache: PagedKVCache):
        """Advance all pending sequences by one chunk; returns (logits, cache).

        tokens (R, C) int32; counts/slots/starts (R,) host arrays.  Rows
        with ``counts == 0`` are padding.  The attention context is bounded
        by the mapped pages the furthest row needs, bucketed to the next
        power of two so the jit cache stays small.
        """
        counts = np.asarray(counts, np.int32)
        starts = np.asarray(starts, np.int32)
        slots = np.asarray(slots, np.int32)
        page = cache.page_size
        need = int(max(1, -(-int((starts + counts).max()) // page)))
        ctx = 1
        while ctx < need:
            ctx *= 2
        ctx = min(ctx, cache.pages_per_seq)
        fn = self._cached_program(
            (page, ctx), lambda: self._prefill(page, ctx)
        )
        with _donation_noop_ok():
            logits, kp, vp, ks, vs, new_len = fn(
                self.params, jnp.asarray(tokens), jnp.asarray(counts),
                jnp.asarray(slots), jnp.asarray(starts),
                cache.k_pages, cache.v_pages, cache.k_scale, cache.v_scale,
                cache.page_table, cache.lengths,
            )
        real = counts > 0
        lens_host = cache.lengths_host
        if lens_host is not None:
            lens_host = lens_host.copy()
            lens_host[slots[real]] = (starts + counts)[real]
        cache = dataclasses.replace(
            cache, k_pages=kp, v_pages=vp, k_scale=ks, v_scale=vs,
            lengths=new_len, lengths_host=lens_host,
        )
        return logits, cache

    def prefill_chunk(self, tokens, count: int, seq: int, start: int,
                      cache: PagedKVCache):
        """Single-sequence chunked prefill (the R=1 row of the batched path)."""
        logits, cache = self.prefill_batch(
            np.asarray(tokens, np.int32)[None, :],
            np.asarray([count], np.int32),
            np.asarray([seq], np.int32),
            np.asarray([start], np.int32),
            cache,
        )
        return logits[0], cache


class PagedFamily(ServableFamily):
    """:class:`ServableFamily` over a :class:`PagedLM` + :class:`PagedKVCache`.

    Resource units are physical pages; traffic accounting is the indirect
    dialect (``page_table_streams`` / ``paged_decode_traffic`` — the page
    table as a memory-resident index vector).  Every method delegates to
    the exact calls the scheduler used to make directly, with identical
    argument values, so PagedLM serving output and Traffic/stream records
    are bit-for-bit unchanged by the protocol indirection.

    The family is the *stateful* face of the functional cache: pool-mutating
    methods rebind ``self.cache`` to the returned cache, and the scheduler
    only ever reads the pool through the family (or its ``Scheduler.cache``
    compatibility property).
    """

    name = "paged"

    def __init__(self, model: PagedLM, cache: PagedKVCache):
        # Element width drives the traffic accounting AND the math the model
        # runs, so any model/cache width mismatch (not just int8-vs-float)
        # must fail loudly rather than mis-report PACK bytes.
        if jnp.dtype(model.kv_dtype) != jnp.dtype(cache.k_pages.dtype):
            raise ValueError(
                f"model kv_dtype ({jnp.dtype(model.kv_dtype).name}) does not "
                f"match the cache pool dtype ({cache.k_pages.dtype.name}): "
                "create both with the same kv_dtype"
            )
        self.model = model
        self.cache = cache
        # Drafter state (speculative decoding): lazily initialized at the
        # first verify launch, then family-resident across launches.
        self._drafter_state = None

    # -- geometry -----------------------------------------------------------

    @property
    def batch(self) -> int:
        return self.cache.page_table.shape[0]

    @property
    def vocab(self) -> int:
        return self.model.cfg.vocab

    @property
    def total_units(self) -> int:
        return self.cache.total_pages

    @property
    def free_units(self) -> int:
        return self.cache.n_free

    @property
    def slot_token_capacity(self) -> int:
        return self.cache.pages_per_seq * self.cache.page_size

    @property
    def page_size(self) -> int:
        return self.cache.page_size

    @property
    def pool_bytes(self) -> int:
        return self.cache.pool_bytes

    def units_for(self, n_tokens: int) -> int:
        return self.cache.pages_for(n_tokens)

    def mapped_units(self, slot: int) -> int:
        return self.cache._mapped(slot)

    def token_capacity(self, slot: int) -> int:
        return self.cache._mapped(slot) * self.cache.page_size

    def state_bytes(self, n_tokens: int) -> int:
        return n_tokens * self.model.kv_token_bytes

    def lengths(self) -> np.ndarray:
        if self.cache.lengths_host is not None:
            return self.cache.lengths_host
        return np.asarray(self.cache.lengths)

    def _host_table(self) -> np.ndarray:
        if self.cache.page_table_host is not None:
            return self.cache.page_table_host
        return np.asarray(self.cache.page_table)

    # -- lifecycle ----------------------------------------------------------

    def alloc_state(self, slot: int, units: int) -> None:
        self.cache = self.cache.allocate(slot, units)

    def trim(self, slot: int, keep_units: int) -> None:
        self.cache = self.cache.trim(slot, keep_units)

    def release(self, slot: int) -> None:
        self.cache = self.cache.release(slot)

    # replay(): inherited no-op — freshly allocated pages hold no live KV,
    # so re-prefill after eviction rebuilds the slot from nothing already.

    # -- model compute ------------------------------------------------------

    def prefill_batch(self, tokens, counts, slots, starts):
        logits, self.cache = self.model.prefill_batch(
            tokens, counts, slots, starts, self.cache
        )
        return logits

    def decode_steps(self, tokens, active, n: int) -> np.ndarray:
        out, self.cache = self.model.decode_upto(
            tokens, self.cache, active, n
        )
        return out

    # -- speculative verify --------------------------------------------------

    @property
    def spec_k(self) -> int:
        return self.model.spec_k

    def verify_steps(self, tokens, active,
                     n: int) -> Tuple[np.ndarray, np.ndarray]:
        """``n`` fused draft→verify→accept launches over ``active`` slots.

        Returns ``(toks (n, B, spec_k), counts (n, B))`` host arrays —
        one sync at the boundary.  Drafter state is family-resident and
        carried across launches; it only shapes acceptance rate, so
        evictions/replays never need to snapshot or reset it for
        bit-exactness (they keep whatever it learned).
        """
        if self._drafter_state is None:
            self._drafter_state = self.model.drafter.init_state(self.batch)
        toks, counts, self.cache, self._drafter_state = \
            self.model.verify_upto(
                tokens, self.cache, active, n, self._drafter_state
            )
        return toks, counts

    def verify_account(self, lens0: np.ndarray, active,
                       counts: np.ndarray) -> List[Tuple[Traffic, tuple]]:
        """Per-launch-step (Traffic, streams) for a verify run that just
        completed.  Unlike :meth:`step_streams` this runs *after* the
        launch: per-step context lengths depend on data-dependent
        acceptance, so they are reconstructed from the pre-launch length
        shadow ``lens0`` plus the synced emitted ``counts`` (n, B) — the
        scored count per step is re-derived with the same
        ``min(spec_k, caps - len)`` clamp the device loop applied."""
        k = self.model.spec_k
        page = self.cache.page_size
        b = self.batch
        table = np.array(self._host_table())
        slots = np.nonzero(np.asarray(active))[0]
        caps = np.array(
            [self.cache._mapped(s) * page for s in range(b)], np.int64
        )
        lens = np.asarray(lens0, np.int64).copy()
        accounts: List[Tuple[Traffic, tuple]] = []
        for s in range(counts.shape[0]):
            scored = np.zeros((b,), np.int64)
            scored[slots] = np.clip(caps[slots] - lens[slots], 0, k)
            traffic = spec_verify_traffic(
                lens, scored, page, self.cache.pages_per_seq,
                self.model.kv_token_bytes,
                elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            )
            streams = verify_table_streams(
                table, lens, scored, page, self.model.kv_token_bytes,
                kv_elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            )
            accounts.append((traffic, streams))
            lens += np.asarray(counts[s], np.int64)
        return accounts

    # -- traffic accounting -------------------------------------------------

    def step_streams(self, active, n: int) -> List[Tuple[Traffic, tuple]]:
        """Per-step indirect accounting for the next ``n`` fused decode
        steps, from the same host shadows the old scheduler read: the
        page-table snapshot before the launch and ``lens0 + s + 1`` per
        step ``s``."""
        b = self.batch
        lens0 = self.lengths().copy()
        table = np.array(self._host_table())
        slots = np.nonzero(np.asarray(active))[0]
        accounts: List[Tuple[Traffic, tuple]] = []
        for s in range(n):
            step_lens = np.zeros((b,), np.int64)
            for slot in slots:
                step_lens[slot] = int(lens0[slot]) + s + 1
            streams = page_table_streams(
                table, step_lens,
                self.cache.page_size, self.model.kv_token_bytes,
                kv_elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            )
            traffic = paged_decode_traffic(
                step_lens[step_lens > 0], self.cache.page_size,
                self.cache.pages_per_seq, self.model.kv_token_bytes,
                elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            )
            accounts.append((traffic, streams))
        return accounts

    def prefill_account(self, slots, starts, counts) -> Tuple[Traffic, tuple]:
        table = self._host_table()
        traffic = paged_prefill_traffic(
            starts, counts,
            self.cache.page_size, self.cache.pages_per_seq,
            self.model.kv_token_bytes,
            elem_bits=self.model.kv_elem_bits,
            scale_bytes_per_token=self.model.kv_scale_token_bytes,
        )
        streams = prefill_table_streams(
            table[slots],  # fancy indexing: bounded per-row copy
            starts, counts,
            self.cache.page_size, self.model.kv_token_bytes,
            kv_elem_bits=self.model.kv_elem_bits,
            scale_bytes_per_token=self.model.kv_scale_token_bytes,
        )
        return traffic, streams

    # -- prefix sharing -----------------------------------------------------

    @property
    def supports_prefix_sharing(self) -> bool:
        return self.cache.refcounts is not None

    def share(self, slot: int, unit_ids: List[int]) -> None:
        self.cache = self.cache.share(slot, unit_ids)

    def retain_units(self, unit_ids: List[int]) -> None:
        self.cache = self.cache.retain_pages(unit_ids)

    def release_units(self, unit_ids: List[int]) -> None:
        self.cache = self.cache.release_pages(unit_ids)

    def unit_refcount(self, unit_id: int) -> int:
        return int(self.cache.refcounts[unit_id])

    def slot_unit_ids(self, slot: int) -> List[int]:
        row = self._host_table()[slot]
        return [int(p) for p in row[: self.cache._mapped(slot)]]

    def ensure_writable(self, slot: int, lo_token: int,
                        hi_token: int) -> int:
        self.cache, n_cow = self.cache.ensure_writable(
            slot, lo_token, hi_token
        )
        return n_cow

    def share_account(self, shared_tokens: int,
                      unit_ids: Sequence[int]) -> Tuple[Traffic, tuple]:
        page = self.cache.page_size
        traffic = prefix_share_traffic(
            shared_tokens, len(unit_ids), page,
            self.model.kv_token_bytes,
            elem_bits=self.model.kv_elem_bits,
            scale_bytes_per_token=self.model.kv_scale_token_bytes,
        )
        streams = share_table_streams(
            unit_ids, page, self.model.kv_token_bytes,
            kv_elem_bits=self.model.kv_elem_bits,
            scale_bytes_per_token=self.model.kv_scale_token_bytes,
        )
        return traffic, streams

    # -- invariants ---------------------------------------------------------

    def check_integrity(self, retained: int = 0) -> None:
        self.cache.check_integrity(retained=retained)


def static_batch_generate(
    model: PagedLM,
    cache: PagedKVCache,
    prompts: Sequence[np.ndarray],
    max_new: int,
    chunk: int = 8,
) -> Dict[int, List[int]]:
    """Reference: all prompts prefilled up front, then one static decode batch.

    Uses the same jitted single-step prefill/decode building blocks the
    scheduler's fused fast path is made of (one-row ``prefill_batch`` calls,
    ``decode_step`` with host-side argmax), so scheduled continuous batching
    must reproduce these tokens bit-for-bit (asserted in
    tests/test_scheduler.py).  Requires a pool large enough to hold every
    sequence at once.
    """
    b = cache.page_table.shape[0]
    assert len(prompts) <= b, "static batch needs one slot per prompt"
    out: Dict[int, List[int]] = {}
    for i, prompt in enumerate(prompts):
        cache = cache.allocate(i, cache.pages_for(len(prompt) + max_new))
        toks: List[int] = []
        for start in range(0, len(prompt), chunk):
            count = min(chunk, len(prompt) - start)
            buf = np.zeros((chunk,), np.int32)
            buf[:count] = np.asarray(prompt)[start:start + count]
            logits, cache = model.prefill_chunk(
                jnp.asarray(buf), count, i, start, cache
            )
        toks.append(int(np.argmax(np.asarray(logits)[: model.cfg.vocab])))
        out[i] = toks
    for _ in range(max_new - 1):
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for i in range(len(prompts)):
            tokens[i] = out[i][-1]
            active[i] = True
        logits, cache = model.decode_step(
            jnp.asarray(tokens), cache, jnp.asarray(active)
        )
        nxt = np.argmax(np.asarray(logits)[:, : model.cfg.vocab], axis=-1)
        for i in range(len(prompts)):
            out[i].append(int(nxt[i]))
    return out
