"""Continuous-batching request scheduler over paged AXI-Pack streams.

The serving-side payoff of the paper's indirect streams: a fixed physical
page pool, per-sequence page tables as memory-resident index vectors, and a
scheduler that keeps the pool full of *useful* pages.  Requests of arbitrary
length enter and leave mid-flight; every decode step is one batched
``paged_decode_attention`` launch whose operands — and whose BASE-vs-PACK
traffic accounting — are derived from the same
:func:`repro.core.streams.page_table_streams` descriptors.

Scheduling policy (vLLM-shaped, deliberately simple and deterministic):

* **Admission** — FIFO.  A waiting request is admitted when a batch slot is
  free and the pool holds pages for its whole prompt plus one decode page of
  headroom.  Prompt pages are allocated at admission; decode pages on demand.
* **Prefill** — chunked and batched: each scheduler step advances *every*
  pending request by one fixed-size chunk in a single
  ``PagedLM.prefill_batch`` call, interleaved with decode (prefill never
  starves decode and vice versa).  Each prefill step records its
  :func:`repro.core.streams.prefill_table_streams` descriptors (context
  read + chunk write per row) and ``paged_prefill_traffic`` the way decode
  steps already record theirs.
* **Decode fast path** — between scheduling boundaries (admission, prefill,
  page growth, retirement) every decode quantity is known on the host, so
  the scheduler *fuses* all steps up to the next boundary into device-
  resident ``PagedLM.decode_steps`` launches (greedy sampling on device,
  pools donated in place) and syncs the token matrix back exactly once per
  boundary.  When nothing can be admitted or prefilled first, pages for
  each request's remaining generation are preallocated from the free pool
  (lookahead never evicts), so page growth stops being a boundary.
  Per-step ``page_table_streams``/``paged_decode_traffic`` records are
  reconstructed from host-side shadow lengths, so the PACK-vs-BASE
  accounting is unchanged from the step-at-a-time path.
* **Eviction** — when a decode step needs a page and the pool is empty, the
  *youngest* resident request is preempted: its pages return to the pool and
  it re-enters the queue front.  On re-admission its prompt is re-prefilled
  and its previously generated tokens are *replayed through the decode
  path* (outputs discarded), which rebuilds its KV bit-for-bit — so
  eviction is invisible in the output stream.  Replay inputs are forced
  from the recorded tokens at every fused-launch boundary; *within* a
  fused launch the device feeds its own greedy argmax, which matches the
  recorded tokens because the model is deterministic and row-wise (the
  property the equivalence tests assert) — a future nondeterministic
  kernel would have to cap fusion during replay.
* **Prefix sharing** (opt-in, ``prefix_sharing=True``) — a
  :class:`PrefixIndex` maps page-aligned prompt chunks to the physical
  pages that hold them.  Admission looks the new prompt up and maps every
  matched page by refcount bump (``PagedKVCache.share``), prefilling only
  the divergent tail; completed prefills register their full prompt pages,
  and retired requests' pages are *retained* by the index (LRU) so later
  requests on the same system prompt hit the pool without it being
  resident.  Writes never land in a shared page: admission privatizes the
  boundary page up front via copy-on-write (``ensure_writable``), and the
  prefill/decode paths carry the same guard defensively.  Under pool
  pressure retained pages are dropped LRU-first before any resident is
  evicted; eviction/replay re-derives shared mappings through the same
  lookup, so replay stays bit-for-bit (shared pages are reused, never
  re-quantized differently in int8 mode).  Admission briefly *defers* a
  request whose prefix is still being prefilled by a resident sibling, so
  concurrent arrivals with one system prompt share it instead of each
  prefilling privately.
* **Hooks** — ``on_token(request, token)`` streams each newly generated
  token; ``on_finish(request)`` fires at completion.

Every decode step records a :class:`repro.core.packing.Traffic`: BASE is the
padded contiguous cache a packing-oblivious server would stream, PACK is the
mapped pages plus the near-memory page-table fetch — connecting serving
throughput back to the Fig. 3 bus model.  Under int8 page pools
(``kv_dtype='int8'`` on both the model and cache) the records carry the
8-bit element width, so PACK shows the quadrupled packing factor while
BASE keeps full-width slots (the narrow-beat penalty).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import OrderedDict, deque
from typing import (
    Callable, Deque, Dict, FrozenSet, Iterator, List, Optional, Sequence,
    Tuple,
)

import jax.numpy as jnp
import numpy as np

from repro.core.packing import (
    Traffic,
    paged_decode_traffic,
    paged_prefill_traffic,
    prefix_share_traffic,
)
from repro.core.streams import (
    IndirectStream,
    page_table_streams,
    prefill_table_streams,
    share_table_streams,
)
from .engine import OutOfPages, PagedKVCache, PagedLM

__all__ = [
    "PrefixIndex",
    "Request",
    "RequestState",
    "Scheduler",
    "StepRecord",
    "ServeStats",
    "build_prefill_rows",
    "static_batch_generate",
]


class PrefixIndex:
    """Prompt-prefix → physical-page index over page-aligned token chunks.

    Entry ``k`` of a prompt is keyed by the byte string of its first
    ``(k+1)·page`` tokens and maps to the physical page holding tokens
    ``[k·page, (k+1)·page)``.  Keying each page by the *cumulative* chunk
    (not just its own tokens) makes the mapping exact — two prompts share
    entry ``k`` iff they agree on every token up to that page boundary — so
    a lookup walk needs no verification pass and cannot alias.

    The index holds one refcount owner per registered page
    (``PagedKVCache.retain_pages``), which is what keeps a retired prompt's
    prefix resident.  Entries are LRU-ordered; the scheduler drops them
    oldest-first under pool pressure.
    """

    def __init__(self, page_size: int):
        self.page = page_size
        #: key → physical page id, in LRU order (oldest first).
        self.entries: "OrderedDict[bytes, int]" = OrderedDict()

    def chunks(self, prompt) -> Iterator[bytes]:
        """Cumulative page-aligned chunk keys of ``prompt``, in order."""
        pr = np.ascontiguousarray(np.asarray(prompt, dtype=np.int64))
        for k in range(len(pr) // self.page):
            yield pr[: (k + 1) * self.page].tobytes()

    def prefix_keys(self, prompt, n: int) -> FrozenSet[bytes]:
        """The first ``n`` chunk keys of ``prompt`` (a lookup's match set)."""
        out = []
        for k, key in enumerate(self.chunks(prompt)):
            if k >= n:
                break
            out.append(key)
        return frozenset(out)

    def match_len(self, prompt) -> int:
        """Longest indexed prefix of ``prompt``, in pages (LRU untouched)."""
        n = 0
        for key in self.chunks(prompt):
            if key not in self.entries:
                break
            n += 1
        return n

    def lookup(self, prompt) -> List[int]:
        """Physical pages of the longest indexed prefix; refreshes LRU."""
        ids: List[int] = []
        for key in self.chunks(prompt):
            page_id = self.entries.get(key)
            if page_id is None:
                break
            self.entries.move_to_end(key)
            ids.append(page_id)
        return ids

    def register(self, prompt, page_ids: Sequence[int]) -> List[int]:
        """Index ``prompt``'s full pages; returns the newly retained ones.

        Existing entries win (first prefill of a prefix is the canonical
        copy) — the caller must bump refcounts for exactly the returned
        pages.
        """
        new: List[int] = []
        for k, key in enumerate(self.chunks(prompt)):
            if k >= len(page_ids):
                break
            if key in self.entries:
                self.entries.move_to_end(key)
                continue
            self.entries[key] = int(page_ids[k])
            new.append(int(page_ids[k]))
        return new

    def pop_chain(self, key: bytes,
                  keep: FrozenSet[bytes] = frozenset()) -> List[int]:
        """Drop ``key`` and every entry extending it; returns their pages.

        Dropping the extensions keeps every remaining entry reachable from
        a fresh lookup walk (an entry whose ancestor is gone could never be
        matched again and would leak its retention).  ``keep`` protects a
        chain a pending admission has just matched.
        """
        pages: List[int] = []
        for k2 in [k for k in self.entries if k.startswith(key)]:
            if k2 in keep:
                continue
            pages.append(self.entries.pop(k2))
        return pages

    def pop_all(self) -> List[int]:
        """Drop every entry; returns all retained pages."""
        pages = list(self.entries.values())
        self.entries.clear()
        return pages


def build_prefill_rows(
    items: Sequence[Tuple[np.ndarray, int, int]], chunk: int, batch: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Assemble one batched-prefill call from pending (prompt, start, slot)s.

    Rows are pow2-bucketed to the pending count (never padded to the full
    batch): compute scales with actual prefill work while the jit cache
    stays O(log batch).  Returns ``(tokens (R, chunk), counts, slots,
    starts)`` with zero-filled padding rows past the pending set.  Single
    source of the bucketing/assembly shared by ``Scheduler._prefill_all``
    and the serving benchmark's isolated prefill phase — so the benchmark
    times exactly the calls the scheduler issues.
    """
    rows = min(1 << max(len(items) - 1, 0).bit_length(), batch)
    toks = np.zeros((rows, chunk), np.int32)
    counts = np.zeros((rows,), np.int32)
    slots = np.zeros((rows,), np.int32)
    starts = np.zeros((rows,), np.int32)
    for i, (prompt, start, slot) in enumerate(items):
        count = min(chunk, len(prompt) - start)
        toks[i, :count] = prompt[start:start + count]
        counts[i], slots[i], starts[i] = count, slot, start
    return toks, counts, slots, starts


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request.

    ``generated`` includes every sampled token (the first comes from the
    prompt's last prefill logits).  ``fed`` counts decode inputs consumed
    since the last (re-)prefill: while ``fed + 1 < len(generated)`` the
    request is replaying after an eviction and decode outputs are discarded.
    """

    rid: int
    prompt: np.ndarray
    max_new: int
    on_token: Optional[Callable[["Request", int], None]] = None
    on_finish: Optional[Callable[["Request"], None]] = None

    state: RequestState = RequestState.WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    prefill_pos: int = 0      # prompt tokens already prefilled
    fed: int = 0              # decode inputs consumed since (re-)prefill
    n_evictions: int = 0
    admit_order: int = -1

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new

    @property
    def replaying(self) -> bool:
        return self.fed + 1 < len(self.generated)


@dataclasses.dataclass
class StepRecord:
    """Per-model-step accounting (a fused launch emits one record per step)."""

    step: int
    kind: str                 # 'decode' | 'prefill' | 'share'
    n_active: int
    new_tokens: int
    traffic: Optional[Traffic]
    streams: Tuple[IndirectStream, ...] = ()


@dataclasses.dataclass
class ServeStats:
    records: List[StepRecord] = dataclasses.field(default_factory=list)
    n_evictions: int = 0
    wall_s: float = 0.0
    prefill_tokens_saved: int = 0   # prompt tokens mapped instead of prefilled
    cow_copies: int = 0             # copy-on-write page copies performed

    @property
    def decode_steps(self) -> int:
        return sum(1 for r in self.records if r.kind == "decode")

    @property
    def tokens(self) -> int:
        return sum(r.new_tokens for r in self.records)

    def _sum(self, attr: str, kind: str = "decode") -> int:
        return sum(
            getattr(r.traffic, attr)
            for r in self.records
            if r.kind == kind and r.traffic is not None
        )

    @property
    def base_bytes(self) -> int:
        return self._sum("base_bytes")

    @property
    def pack_bytes(self) -> int:
        return self._sum("pack_bytes") + self._sum("index_bus_bytes_pack")

    @property
    def useful_bytes(self) -> int:
        return self._sum("useful_bytes")

    @property
    def base_efficiency(self) -> float:
        return self.useful_bytes / self.base_bytes if self.base_bytes else 1.0

    @property
    def pack_efficiency(self) -> float:
        return self.useful_bytes / self.pack_bytes if self.pack_bytes else 1.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0

    # -- prefill-side aggregates (same Traffic records, kind='prefill') ------

    @property
    def prefill_steps(self) -> int:
        return sum(1 for r in self.records if r.kind == "prefill")

    @property
    def prefill_base_bytes(self) -> int:
        return self._sum("base_bytes", "prefill")

    @property
    def prefill_pack_bytes(self) -> int:
        return (self._sum("pack_bytes", "prefill")
                + self._sum("index_bus_bytes_pack", "prefill"))

    @property
    def prefill_useful_bytes(self) -> int:
        return self._sum("useful_bytes", "prefill")

    @property
    def prefill_base_efficiency(self) -> float:
        b = self.prefill_base_bytes
        return self.prefill_useful_bytes / b if b else 1.0

    @property
    def prefill_pack_efficiency(self) -> float:
        p = self.prefill_pack_bytes
        return self.prefill_useful_bytes / p if p else 1.0

    # -- prefix-sharing aggregates (kind='share' records) --------------------

    @property
    def shared_pages(self) -> int:
        """Physical pages mapped by refcount bump instead of prefilled."""
        return sum(
            r.traffic.shared_pages
            for r in self.records
            if r.traffic is not None
        )

    @property
    def share_events(self) -> int:
        return sum(1 for r in self.records if r.kind == "share")

    @property
    def shared_useful_bytes(self) -> int:
        return self._sum("useful_bytes", "share")

    @property
    def shared_index_bytes(self) -> int:
        return self._sum("index_bus_bytes_pack", "share")

    @property
    def prefill_effective_pack_efficiency(self) -> float:
        """Prefill-side PACK efficiency with dedup folded in.

        Bytes of prompt KV the pool ends up serving (prefilled + shared)
        over the bytes PACK actually moved to get there (prefill payload
        and table fetches, plus the share remaps' table fetches).  Exceeds
        :attr:`prefill_pack_efficiency` exactly when prefix sharing elided
        prefill work — the dedup-before-packing multiplier; unlike a plain
        packing ratio it can exceed 1.
        """
        moved = (self.prefill_pack_bytes
                 + self._sum("pack_bytes", "share")
                 + self.shared_index_bytes)
        served = self.prefill_useful_bytes + self.shared_useful_bytes
        return served / moved if moved else 1.0


class Scheduler:
    """Continuous-batching scheduler driving a :class:`PagedLM`."""

    def __init__(self, model: PagedLM, cache: PagedKVCache, chunk: int = 8,
                 prefix_sharing: bool = False):
        # Element width drives the traffic accounting AND the math the model
        # runs, so any model/cache width mismatch (not just int8-vs-float)
        # must fail loudly rather than mis-report PACK bytes.
        if jnp.dtype(model.kv_dtype) != jnp.dtype(cache.k_pages.dtype):
            raise ValueError(
                f"model kv_dtype ({jnp.dtype(model.kv_dtype).name}) does not "
                f"match the cache pool dtype ({cache.k_pages.dtype.name}): "
                "create both with the same kv_dtype"
            )
        if prefix_sharing and cache.refcounts is None:
            raise ValueError("prefix_sharing requires a refcounted cache")
        self.model = model
        self.cache = cache
        self.chunk = chunk
        self.prefix_index: Optional[PrefixIndex] = (
            PrefixIndex(cache.page_size) if prefix_sharing else None
        )
        self.queue: Deque[Request] = deque()
        self.resident: List[Request] = []      # admission order
        self.finished: Dict[int, Request] = {}
        self.stats = ServeStats()
        self._step = 0
        self._admit_counter = 0
        self._free_slots = list(range(cache.page_table.shape[0]))[::-1]

    # -- public API ---------------------------------------------------------

    @staticmethod
    def _max_kv(request: Request) -> int:
        # The last generated token is never fed back, so KV peaks one short.
        return request.prompt_len + max(request.max_new - 1, 0)

    def submit(self, request: Request) -> None:
        worst = self.cache.pages_for(self._max_kv(request))
        if worst > self.cache.total_pages:
            raise OutOfPages(
                f"request {request.rid} needs up to {worst} pages; the pool "
                f"holds {self.cache.total_pages}"
            )
        if self._max_kv(request) > (
            self.cache.pages_per_seq * self.cache.page_size
        ):
            raise ValueError(
                f"request {request.rid} exceeds the per-sequence table row"
            )
        if request.max_new < 1:
            raise ValueError(
                f"request {request.rid}: max_new must be >= 1"
            )
        request.state = RequestState.WAITING
        self.queue.append(request)

    def run(self, max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive all submitted requests to completion."""
        t0 = time.perf_counter()
        while (self.queue or self.resident) and self._step < max_steps:
            self.step()
        self.stats.wall_s += time.perf_counter() - t0
        if self.queue or self.resident:
            raise RuntimeError(f"scheduler stalled after {max_steps} steps")
        return {rid: r.generated for rid, r in sorted(self.finished.items())}

    def step(self) -> None:
        """One scheduler iteration: admit → one batched prefill chunk → fused
        decode to the next scheduling boundary → retire."""
        self._step += 1
        self._admit()
        self._prefill_all()
        self._decode()
        self._retire()

    # -- host shadow state ---------------------------------------------------

    def _lengths(self) -> np.ndarray:
        """Per-slot KV lengths without touching the device."""
        if self.cache.lengths_host is not None:
            return self.cache.lengths_host
        return np.asarray(self.cache.lengths)

    # -- admission ----------------------------------------------------------

    def _reclaim_lookahead(self, need: int) -> None:
        """Trim residents' unwritten lookahead pages back to the free pool.

        Lookahead prealloc (see ``_grow_pages``) may have mapped pages for
        generations that have not happened yet; those pages hold no KV, so
        reclaiming them for an admission is loss-free — the residents simply
        fall back to on-demand growth.  Trims youngest-first, down to each
        request's written content (prompt pages for a request still in
        prefill)."""
        for r in sorted(self.resident, key=lambda x: -x.admit_order):
            if self.cache.n_free >= need:
                return
            if r.state is RequestState.PREFILL:
                floor = self.cache.pages_for(r.prompt_len)
            else:
                floor = self.cache.pages_for(
                    int(self._lengths()[r.slot])
                )
            self.cache = self.cache.trim(r.slot, floor)

    def _drop_retained(self, need: int,
                       keep: FrozenSet[bytes] = frozenset()) -> None:
        """Release retained prefix entries (LRU-first) until ``need`` free.

        An entry whose page is still shared with a resident frees nothing
        when dropped, so it is skipped; ``keep`` protects the chain a
        pending admission has just matched.  Dropping an entry drops its
        whole extension chain (see :meth:`PrefixIndex.pop_chain`).
        """
        if self.prefix_index is None:
            return
        for key in list(self.prefix_index.entries):
            if self.cache.n_free >= need:
                return
            if key not in self.prefix_index.entries or key in keep:
                continue  # already popped as part of an earlier chain
            page_id = self.prefix_index.entries[key]
            if self.cache.refcounts[page_id] > 1:
                continue
            pages = self.prefix_index.pop_chain(key, keep=keep)
            self.cache = self.cache.release_pages(pages)

    def flush_prefix_cache(self) -> None:
        """Drop every retained prefix entry; unshared pages return to free."""
        if self.prefix_index is None:
            return
        self.cache = self.cache.release_pages(self.prefix_index.pop_all())

    def _defer_for_inflight_prefix(self, r: Request) -> bool:
        """Hold admission while a still-prefilling resident is building a
        longer shared prefix for ``r`` than the index already offers.

        Registration happens at prefill completion, so concurrent arrivals
        with a common system prompt would otherwise each prefill it
        privately; waiting one scheduling boundary converts the later ones
        into refcount bumps.  Terminates because prefill advances every
        pending resident each step: the sibling either completes (and
        registers at least the pages counted here) or is evicted (and the
        defer condition vanishes).
        """
        assert self.prefix_index is not None
        page = self.cache.page_size
        pr = np.asarray(r.prompt, dtype=np.int64)
        have = self.prefix_index.match_len(r.prompt)
        for s in self.resident:
            if s.state is not RequestState.PREFILL:
                continue
            ps = np.asarray(s.prompt, dtype=np.int64)
            limit = min(len(pr), (s.prompt_len // page) * page) // page
            n = 0
            while (n < limit and np.array_equal(
                    pr[n * page:(n + 1) * page], ps[n * page:(n + 1) * page])):
                n += 1
            if n > have:
                return True
        return False

    def _admit(self) -> None:
        while self.queue and self._free_slots:
            r = self.queue[0]
            shared: List[int] = []
            if self.prefix_index is not None:
                if self._defer_for_inflight_prefix(r):
                    return
                shared = self.prefix_index.lookup(r.prompt)
            page = self.cache.page_size
            shared_tokens = len(shared) * page
            # Admission always (re-)prefills at least the prompt's last
            # token, so completing prefill yields fresh last-token logits.
            # A fully page-aligned match therefore writes one token into
            # its final *shared* page — privatized eagerly below via
            # copy-on-write, with the extra page counted in ``need`` so two
            # same-step admissions can't both claim the same free page.
            tail_start = min(shared_tokens, r.prompt_len - 1)
            cow_extra = 1 if shared_tokens > tail_start else 0
            # Pages for the whole prompt, plus one decode page of headroom
            # when the first appended token will cross a page boundary.
            need = (self.cache.pages_for(
                min(r.prompt_len + 1, self._max_kv(r))
            ) - len(shared) + cow_extra)
            if self.cache.n_free < need:
                self._reclaim_lookahead(need)
            if self.cache.n_free < need and self.prefix_index is not None:
                self._drop_retained(
                    need,
                    keep=self.prefix_index.prefix_keys(r.prompt, len(shared)),
                )
            if self.cache.n_free < need:
                return
            self.queue.popleft()
            r.slot = self._free_slots.pop()
            r.state = RequestState.PREFILL
            r.prefill_pos = tail_start
            r.fed = 0
            r.admit_order = self._admit_counter
            self._admit_counter += 1
            self.cache = self.cache.share(r.slot, shared)
            fresh = self.cache.pages_for(r.prompt_len) - len(shared)
            if fresh > 0:
                self.cache = self.cache.allocate(r.slot, fresh)
            if cow_extra:
                self.cache, n_cow = self.cache.ensure_writable(
                    r.slot, tail_start, tail_start
                )
                self.stats.cow_copies += n_cow
            if shared:
                # Replay after eviction walks this same path: the lookup
                # re-derives the mappings, so re-admission reuses the pages
                # (bit-identical KV, int8 scales included) it had before.
                self.stats.prefill_tokens_saved += tail_start
                self.stats.records.append(StepRecord(
                    step=self._step, kind="share", n_active=1, new_tokens=0,
                    traffic=prefix_share_traffic(
                        tail_start, len(shared), page,
                        self.model.kv_token_bytes,
                        elem_bits=self.model.kv_elem_bits,
                        scale_bytes_per_token=self.model.kv_scale_token_bytes,
                    ),
                    streams=share_table_streams(
                        shared, page, self.model.kv_token_bytes,
                        kv_elem_bits=self.model.kv_elem_bits,
                        scale_bytes_per_token=self.model.kv_scale_token_bytes,
                    ),
                ))
            self.resident.append(r)

    # -- prefill ------------------------------------------------------------

    def _prefill_all(self) -> None:
        """One chunk for *every* pending request, in one batched call."""
        pending = [r for r in self.resident if r.state is RequestState.PREFILL]
        if not pending:
            return
        pending.sort(key=lambda x: x.admit_order)
        b = self.cache.page_table.shape[0]
        toks, counts, slots, starts = build_prefill_rows(
            [(r.prompt, r.prefill_pos, r.slot) for r in pending],
            self.chunk, b,
        )
        if self.prefix_index is not None:
            # Defensive: admission privatizes the only shared page a prefill
            # can write (the page-aligned-match boundary), so this is a
            # refcount scan that never copies — unless an invariant broke,
            # in which case copy-on-write still keeps siblings isolated.
            for i, r in enumerate(pending):
                self.cache, n_cow = self.cache.ensure_writable(
                    r.slot, int(starts[i]), int(starts[i] + counts[i]) - 1
                )
                self.stats.cow_copies += n_cow
        logits, self.cache = self.model.prefill_batch(
            toks, counts, slots, starts, self.cache
        )
        new_tokens = 0
        completed = []
        for i, r in enumerate(pending):
            r.prefill_pos += int(counts[i])
            if r.prefill_pos == r.prompt_len:
                r.state = RequestState.RUNNING
                r.fed = 0
                if not r.generated:  # fresh prefill; a replay already has it
                    completed.append((i, r))
                if self.prefix_index is not None:
                    # Register the full prompt pages (the partial last page,
                    # which decode will keep writing, is never indexed) and
                    # give the index its refcount owner on the new entries.
                    t = self.cache.page_table_host
                    row = (t[r.slot] if t is not None
                           else np.asarray(self.cache.page_table)[r.slot])
                    n_full = r.prompt_len // self.cache.page_size
                    new_pages = self.prefix_index.register(
                        r.prompt, [int(p) for p in row[:n_full]]
                    )
                    self.cache = self.cache.retain_pages(new_pages)
        if completed:
            lg = np.asarray(logits)  # host sync: admission boundary only
            for i, r in completed:
                tok = int(np.argmax(lg[i, : self.model.cfg.vocab]))
                r.generated.append(tok)
                new_tokens += 1
                if r.on_token:
                    r.on_token(r, tok)
        # Stream descriptors + traffic from the same host-shadow page math
        # the kernel's scalar-prefetch walk resolves (as decode does).  The
        # model's element width (8-bit for int8 pools) flows into both, so
        # PACK reflects the real packed bytes on the bus.
        table = (self.cache.page_table_host
                 if self.cache.page_table_host is not None
                 else np.asarray(self.cache.page_table))
        n = len(pending)
        self.stats.records.append(StepRecord(
            step=self._step, kind="prefill", n_active=n,
            new_tokens=new_tokens,
            traffic=paged_prefill_traffic(
                starts[:n], counts[:n],
                self.cache.page_size, self.cache.pages_per_seq,
                self.model.kv_token_bytes,
                elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            ),
            streams=prefill_table_streams(
                table[slots[:n]],  # fancy indexing: bounded per-row copy
                starts[:n], counts[:n],
                self.cache.page_size, self.model.kv_token_bytes,
                kv_elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            ),
        ))

    # -- decode -------------------------------------------------------------

    def _fused_steps(self, running: List[Request]) -> int:
        """Decode steps until the next scheduling boundary.

        Between boundaries nothing the scheduler decides on can change: the
        running set is fixed (retirement is a boundary), page tables are
        fixed (growth is a boundary), and admission cannot unblock (slots
        and pages free up only at boundaries).  While any resident is still
        prefilling we keep single steps so prefill stays interleaved.
        """
        if any(r.state is RequestState.PREFILL for r in self.resident):
            return 1
        lens = self._lengths()
        page = self.cache.page_size
        to_done = min(r.max_new - 1 - r.fed for r in running)
        to_growth = min(
            self.cache._mapped(r.slot) * page - int(lens[r.slot])
            for r in running
        )
        return max(1, min(to_done, to_growth))

    def _decode(self) -> None:
        running = [
            r for r in self.resident
            if r.state is RequestState.RUNNING and not r.done
        ]
        if not running:
            return
        running = self._grow_pages(running)
        if not running:
            return
        b = self.cache.page_table.shape[0]
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for r in running:
            tokens[r.slot] = r.generated[r.fed]
            active[r.slot] = True
        lens0 = self._lengths().copy()

        # Fuse up to the boundary: device-resident scan chunks, one token
        # sync at the end (the scheduling boundary).
        n = self._fused_steps(running)
        if self.prefix_index is not None:
            # Defensive: decode appends land past the prompt, and shared
            # pages only ever cover full prompt pages, so this scan never
            # copies unless an invariant broke (see _prefill_all).
            for r in running:
                ln = int(lens0[r.slot])
                self.cache, n_cow = self.cache.ensure_writable(
                    r.slot, ln, ln + n - 1
                )
                self.stats.cow_copies += n_cow
        table = (np.array(self.cache.page_table_host)
                 if self.cache.page_table_host is not None
                 else np.asarray(self.cache.page_table))
        out, self.cache = self.model.decode_upto(
            tokens, self.cache, active, n
        )

        # Per-step records from host shadow lengths: identical accounting to
        # the step-at-a-time path.
        for s in range(n):
            step_lens = np.zeros((b,), np.int64)
            for r in running:
                step_lens[r.slot] = int(lens0[r.slot]) + s + 1
            streams = page_table_streams(
                table, step_lens,
                self.cache.page_size, self.model.kv_token_bytes,
                kv_elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            )
            traffic = paged_decode_traffic(
                step_lens[step_lens > 0], self.cache.page_size,
                self.cache.pages_per_seq, self.model.kv_token_bytes,
                elem_bits=self.model.kv_elem_bits,
                scale_bytes_per_token=self.model.kv_scale_token_bytes,
            )
            new_tokens = 0
            for r in running:
                r.fed += 1
                if r.fed < len(r.generated):
                    continue  # replay after eviction: output already known
                tok = int(out[s, r.slot])
                r.generated.append(tok)
                new_tokens += 1
                if r.on_token:
                    r.on_token(r, tok)
            self.stats.records.append(StepRecord(
                step=self._step, kind="decode", n_active=len(running),
                new_tokens=new_tokens, traffic=traffic, streams=streams,
            ))

    def _grow_pages(self, running: List[Request]) -> List[Request]:
        """Allocate a page for every running request whose next token lands on
        a page boundary, evicting the youngest resident when the pool runs
        dry (the requester itself defers when it *is* the youngest).
        Returns the requests that still run this step."""
        lengths = self._lengths()
        for r in sorted(running, key=lambda x: x.admit_order):
            if r.state is not RequestState.RUNNING:
                continue  # evicted below by an older request's allocation
            ln = int(lengths[r.slot])
            if ln < self.cache._mapped(r.slot) * self.cache.page_size:
                continue  # headroom left in the last mapped page
            while (r.state is RequestState.RUNNING
                   and self.cache.n_free < 1):
                # Retained-but-unshared prefix pages are the cheapest relief
                # (no resident loses work); then evict the youngest.  Each
                # iteration frees a page, removes a resident, or empties the
                # index, so the loop terminates.
                self._drop_retained(1)
                if self.cache.n_free >= 1:
                    break
                victim = max(self.resident, key=lambda x: x.admit_order)
                if victim is r and len(self.resident) == 1:
                    if (self.prefix_index is not None
                            and self.prefix_index.entries):
                        # Last resort: drop retention even for pages this
                        # request shares — it keeps its own mappings.
                        self.flush_prefix_cache()
                        continue
                    # Unreachable given the submit() worst-case guard.
                    raise OutOfPages(
                        "page pool exhausted with a single resident request"
                    )
                self._evict(victim)  # may be r itself: it defers, not others
            if r.state is RequestState.RUNNING:
                self.cache = self.cache.allocate(r.slot, 1)
        still = [r for r in running if r.state is RequestState.RUNNING]
        # Opportunistic lookahead: when nothing can be admitted or prefilled
        # before the next boundary AND the free pool covers *every* running
        # request's full remaining generation, map those pages up front, so
        # page growth stops being a scheduling boundary and decode fuses
        # through.  The all-or-nothing condition means lookahead can never
        # starve a peer's imminent on-demand growth (no extra evictions
        # versus the on-demand policy); under pool pressure it simply stays
        # off and behaviour is exactly the on-demand path.
        if not self.queue and not any(
            x.state is RequestState.PREFILL for x in self.resident
        ):
            lens = self._lengths()
            wants = {
                r.rid: (self.cache.pages_for(
                    int(lens[r.slot]) + (r.max_new - 1 - r.fed)
                ) - self.cache._mapped(r.slot))
                for r in still
            }
            if sum(max(w, 0) for w in wants.values()) <= self.cache.n_free:
                for r in sorted(still, key=lambda x: x.admit_order):
                    if wants[r.rid] > 0:
                        self.cache = self.cache.allocate(r.slot, wants[r.rid])
        return still

    def _evict(self, r: Request) -> None:
        self.cache = self.cache.release(r.slot)
        self.resident.remove(r)
        self._free_slots.append(r.slot)
        r.slot = -1
        r.state = RequestState.WAITING
        r.prefill_pos = 0
        r.fed = 0
        r.n_evictions += 1
        self.stats.n_evictions += 1
        self.queue.appendleft(r)  # re-admit first: FIFO fairness preserved

    # -- retirement ---------------------------------------------------------

    def _retire(self) -> None:
        for r in [x for x in self.resident if x.done]:
            self.cache = self.cache.release(r.slot)
            self.resident.remove(r)
            self._free_slots.append(r.slot)
            r.slot = -1
            r.state = RequestState.FINISHED
            self.finished[r.rid] = r
            if r.on_finish:
                r.on_finish(r)


def static_batch_generate(
    model: PagedLM,
    cache: PagedKVCache,
    prompts: Sequence[np.ndarray],
    max_new: int,
    chunk: int = 8,
) -> Dict[int, List[int]]:
    """Reference: all prompts prefilled up front, then one static decode batch.

    Uses the same jitted single-step prefill/decode building blocks the
    scheduler's fused fast path is made of (one-row ``prefill_batch`` calls,
    ``decode_step`` with host-side argmax), so scheduled continuous batching
    must reproduce these tokens bit-for-bit (asserted in
    tests/test_scheduler.py).  Requires a pool large enough to hold every
    sequence at once.
    """
    b = cache.page_table.shape[0]
    assert len(prompts) <= b, "static batch needs one slot per prompt"
    out: Dict[int, List[int]] = {}
    for i, prompt in enumerate(prompts):
        cache = cache.allocate(i, cache.pages_for(len(prompt) + max_new))
        toks: List[int] = []
        for start in range(0, len(prompt), chunk):
            count = min(chunk, len(prompt) - start)
            buf = np.zeros((chunk,), np.int32)
            buf[:count] = np.asarray(prompt)[start:start + count]
            logits, cache = model.prefill_chunk(
                jnp.asarray(buf), count, i, start, cache
            )
        toks.append(int(np.argmax(np.asarray(logits)[: model.cfg.vocab])))
        out[i] = toks
    for _ in range(max_new - 1):
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for i in range(len(prompts)):
            tokens[i] = out[i][-1]
            active[i] = True
        logits, cache = model.decode_step(
            jnp.asarray(tokens), cache, jnp.asarray(active)
        )
        nxt = np.argmax(np.asarray(logits)[:, : model.cfg.vocab], axis=-1)
        for i in range(len(prompts)):
            out[i].append(int(nxt[i]))
    return out
