"""Continuous-batching request scheduler over paged AXI-Pack streams.

The serving-side payoff of the paper's indirect streams: a fixed physical
page pool, per-sequence page tables as memory-resident index vectors, and a
scheduler that keeps the pool full of *useful* pages.  Requests of arbitrary
length enter and leave mid-flight; every decode step is one batched
``paged_decode_attention`` launch whose operands — and whose BASE-vs-PACK
traffic accounting — are derived from the same
:func:`repro.core.streams.page_table_streams` descriptors.

Scheduling policy (vLLM-shaped, deliberately simple and deterministic):

* **Admission** — FIFO.  A waiting request is admitted when a batch slot is
  free and the pool holds pages for its whole prompt plus one decode page of
  headroom.  Prompt pages are allocated at admission; decode pages on demand.
* **Prefill** — chunked: each scheduler step advances at most one request by
  one fixed-size chunk, interleaved with a batched decode step for all
  running requests (prefill never starves decode).
* **Eviction** — when a decode step needs a page and the pool is empty, the
  *youngest* resident request is preempted: its pages return to the pool and
  it re-enters the queue front.  On re-admission its prompt is re-prefilled
  and its previously generated tokens are *replayed through the decode path*
  (inputs forced, outputs discarded), which rebuilds its KV bit-for-bit —
  so eviction is invisible in the output stream.
* **Hooks** — ``on_token(request, token)`` streams each newly generated
  token; ``on_finish(request)`` fires at completion.

Every decode step records a :class:`repro.core.packing.Traffic`: BASE is the
padded contiguous cache a packing-oblivious server would stream, PACK is the
mapped pages plus the near-memory page-table fetch — connecting serving
throughput back to the Fig. 3 bus model.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.packing import Traffic, paged_decode_traffic
from repro.core.streams import IndirectStream, page_table_streams
from .engine import OutOfPages, PagedKVCache, PagedLM

__all__ = [
    "Request",
    "RequestState",
    "Scheduler",
    "StepRecord",
    "ServeStats",
    "static_batch_generate",
]


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request.

    ``generated`` includes every sampled token (the first comes from the
    prompt's last prefill logits).  ``fed`` counts decode inputs consumed
    since the last (re-)prefill: while ``fed + 1 < len(generated)`` the
    request is replaying after an eviction and decode outputs are discarded.
    """

    rid: int
    prompt: np.ndarray
    max_new: int
    on_token: Optional[Callable[["Request", int], None]] = None
    on_finish: Optional[Callable[["Request"], None]] = None

    state: RequestState = RequestState.WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    prefill_pos: int = 0      # prompt tokens already prefilled
    fed: int = 0              # decode inputs consumed since (re-)prefill
    n_evictions: int = 0
    admit_order: int = -1

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new

    @property
    def replaying(self) -> bool:
        return self.fed + 1 < len(self.generated)


@dataclasses.dataclass
class StepRecord:
    """Per-scheduler-step accounting."""

    step: int
    kind: str                 # 'decode' | 'prefill'
    n_active: int
    new_tokens: int
    traffic: Optional[Traffic]
    streams: Tuple[IndirectStream, ...] = ()


@dataclasses.dataclass
class ServeStats:
    records: List[StepRecord] = dataclasses.field(default_factory=list)
    n_evictions: int = 0
    wall_s: float = 0.0

    @property
    def decode_steps(self) -> int:
        return sum(1 for r in self.records if r.kind == "decode")

    @property
    def tokens(self) -> int:
        return sum(r.new_tokens for r in self.records)

    def _sum(self, attr: str) -> int:
        return sum(
            getattr(r.traffic, attr)
            for r in self.records
            if r.kind == "decode" and r.traffic is not None
        )

    @property
    def base_bytes(self) -> int:
        return self._sum("base_bytes")

    @property
    def pack_bytes(self) -> int:
        return self._sum("pack_bytes") + self._sum("index_bus_bytes_pack")

    @property
    def useful_bytes(self) -> int:
        return self._sum("useful_bytes")

    @property
    def base_efficiency(self) -> float:
        return self.useful_bytes / self.base_bytes if self.base_bytes else 1.0

    @property
    def pack_efficiency(self) -> float:
        return self.useful_bytes / self.pack_bytes if self.pack_bytes else 1.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0


class Scheduler:
    """Continuous-batching scheduler driving a :class:`PagedLM`."""

    def __init__(self, model: PagedLM, cache: PagedKVCache, chunk: int = 8):
        self.model = model
        self.cache = cache
        self.chunk = chunk
        self.queue: Deque[Request] = deque()
        self.resident: List[Request] = []      # admission order
        self.finished: Dict[int, Request] = {}
        self.stats = ServeStats()
        self._step = 0
        self._admit_counter = 0
        self._free_slots = list(range(cache.page_table.shape[0]))[::-1]

    # -- public API ---------------------------------------------------------

    @staticmethod
    def _max_kv(request: Request) -> int:
        # The last generated token is never fed back, so KV peaks one short.
        return request.prompt_len + max(request.max_new - 1, 0)

    def submit(self, request: Request) -> None:
        worst = self.cache.pages_for(self._max_kv(request))
        if worst > self.cache.total_pages:
            raise OutOfPages(
                f"request {request.rid} needs up to {worst} pages; the pool "
                f"holds {self.cache.total_pages}"
            )
        if self._max_kv(request) > (
            self.cache.pages_per_seq * self.cache.page_size
        ):
            raise ValueError(
                f"request {request.rid} exceeds the per-sequence table row"
            )
        if request.max_new < 1:
            raise ValueError(
                f"request {request.rid}: max_new must be >= 1"
            )
        request.state = RequestState.WAITING
        self.queue.append(request)

    def run(self, max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive all submitted requests to completion."""
        t0 = time.perf_counter()
        while (self.queue or self.resident) and self._step < max_steps:
            self.step()
        self.stats.wall_s += time.perf_counter() - t0
        if self.queue or self.resident:
            raise RuntimeError(f"scheduler stalled after {max_steps} steps")
        return {rid: r.generated for rid, r in sorted(self.finished.items())}

    def step(self) -> None:
        """One scheduler iteration: admit → one prefill chunk → one batched
        decode step → retire."""
        self._step += 1
        self._admit()
        self._prefill_one()
        self._decode()
        self._retire()

    # -- admission ----------------------------------------------------------

    def _admit(self) -> None:
        while self.queue and self._free_slots:
            r = self.queue[0]
            # Pages for the whole prompt, plus one decode page of headroom
            # when the first appended token will cross a page boundary.
            need = self.cache.pages_for(
                min(r.prompt_len + 1, self._max_kv(r))
            )
            if self.cache.n_free < need:
                return
            self.queue.popleft()
            r.slot = self._free_slots.pop()
            r.state = RequestState.PREFILL
            r.prefill_pos = 0
            r.fed = 0
            r.admit_order = self._admit_counter
            self._admit_counter += 1
            self.cache = self.cache.allocate(
                r.slot, self.cache.pages_for(r.prompt_len)
            )
            self.resident.append(r)

    # -- prefill ------------------------------------------------------------

    def _prefill_one(self) -> None:
        pending = [r for r in self.resident if r.state is RequestState.PREFILL]
        if not pending:
            return
        r = min(pending, key=lambda x: x.admit_order)
        start = r.prefill_pos
        count = min(self.chunk, r.prompt_len - start)
        toks = np.zeros((self.chunk,), np.int32)
        toks[:count] = r.prompt[start:start + count]
        logits, self.cache = self.model.prefill_chunk(
            jnp.asarray(toks), count, r.slot, start, self.cache
        )
        r.prefill_pos += count
        new_tokens = 0
        if r.prefill_pos == r.prompt_len:
            r.state = RequestState.RUNNING
            r.fed = 0
            if not r.generated:  # fresh prefill; a replayed one already has it
                tok = int(np.argmax(np.asarray(logits)[: self.model.cfg.vocab]))
                r.generated.append(tok)
                new_tokens = 1
                if r.on_token:
                    r.on_token(r, tok)
        self.stats.records.append(StepRecord(
            step=self._step, kind="prefill", n_active=1,
            new_tokens=new_tokens,
            traffic=self._traffic_for(slots=[r.slot]),
        ))

    # -- decode -------------------------------------------------------------

    def _decode(self) -> None:
        running = [
            r for r in self.resident
            if r.state is RequestState.RUNNING and not r.done
        ]
        if not running:
            return
        running = self._grow_pages(running)
        if not running:
            return
        b = self.cache.page_table.shape[0]
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for r in running:
            tokens[r.slot] = r.generated[r.fed]
            active[r.slot] = True

        # Batched indirect-stream descriptors over exactly what this step
        # reads (post-append lengths of the decoding slots): source of truth
        # for both the traffic accounting and the Fig. 3 connection.
        step_lens = np.zeros((b,), np.int64)
        lens_now = np.asarray(self.cache.lengths)
        for r in running:
            step_lens[r.slot] = int(lens_now[r.slot]) + 1
        streams = page_table_streams(
            self.cache.page_table, step_lens,
            self.cache.page_size, self.model.kv_token_bytes,
        )
        traffic = paged_decode_traffic(
            step_lens[step_lens > 0], self.cache.page_size,
            self.cache.pages_per_seq, self.model.kv_token_bytes,
        )

        logits, self.cache = self.model.decode_step(
            jnp.asarray(tokens), self.cache, jnp.asarray(active)
        )
        out = np.argmax(
            np.asarray(logits)[:, : self.model.cfg.vocab], axis=-1
        ).astype(np.int32)

        new_tokens = 0
        for r in running:
            r.fed += 1
            if r.fed < len(r.generated):
                continue  # replay after eviction: output already known
            tok = int(out[r.slot])
            r.generated.append(tok)
            new_tokens += 1
            if r.on_token:
                r.on_token(r, tok)
        self.stats.records.append(StepRecord(
            step=self._step, kind="decode", n_active=len(running),
            new_tokens=new_tokens, traffic=traffic, streams=streams,
        ))

    def _grow_pages(self, running: List[Request]) -> List[Request]:
        """Allocate a page for every running request whose next token lands on
        a page boundary, evicting the youngest resident when the pool runs
        dry (the requester itself defers when it *is* the youngest).
        Returns the requests that still run this step."""
        lengths = np.asarray(self.cache.lengths)
        for r in sorted(running, key=lambda x: x.admit_order):
            if r.state is not RequestState.RUNNING:
                continue  # evicted below by an older request's allocation
            ln = int(lengths[r.slot])
            if ln < self.cache._mapped(r.slot) * self.cache.page_size:
                continue  # headroom left in the last mapped page
            while (r.state is RequestState.RUNNING
                   and self.cache.n_free < 1):
                victim = max(self.resident, key=lambda x: x.admit_order)
                if victim is r and len(self.resident) == 1:
                    # Unreachable given the submit() worst-case guard.
                    raise OutOfPages(
                        "page pool exhausted with a single resident request"
                    )
                self._evict(victim)  # may be r itself: it defers, not others
            if r.state is RequestState.RUNNING:
                self.cache = self.cache.allocate(r.slot, 1)
        return [r for r in running if r.state is RequestState.RUNNING]

    def _evict(self, r: Request) -> None:
        self.cache = self.cache.release(r.slot)
        self.resident.remove(r)
        self._free_slots.append(r.slot)
        r.slot = -1
        r.state = RequestState.WAITING
        r.prefill_pos = 0
        r.fed = 0
        r.n_evictions += 1
        self.stats.n_evictions += 1
        self.queue.appendleft(r)  # re-admit first: FIFO fairness preserved

    # -- retirement ---------------------------------------------------------

    def _retire(self) -> None:
        for r in [x for x in self.resident if x.done]:
            self.cache = self.cache.release(r.slot)
            self.resident.remove(r)
            self._free_slots.append(r.slot)
            r.slot = -1
            r.state = RequestState.FINISHED
            self.finished[r.rid] = r
            if r.on_finish:
                r.on_finish(r)

    # -- accounting ---------------------------------------------------------

    def _traffic_for(self, slots: Sequence[int]) -> Traffic:
        lens = np.asarray(self.cache.lengths)[list(slots)]
        return paged_decode_traffic(
            lens, self.cache.page_size, self.cache.pages_per_seq,
            self.model.kv_token_bytes,
        )


def static_batch_generate(
    model: PagedLM,
    cache: PagedKVCache,
    prompts: Sequence[np.ndarray],
    max_new: int,
    chunk: int = 8,
) -> Dict[int, List[int]]:
    """Reference: all prompts prefilled up front, then one static decode batch.

    Uses the exact same jitted prefill/decode functions as the scheduler, so
    scheduled continuous batching must reproduce these tokens bit-for-bit
    (asserted in tests/test_scheduler.py).  Requires a pool large enough to
    hold every sequence at once.
    """
    b = cache.page_table.shape[0]
    assert len(prompts) <= b, "static batch needs one slot per prompt"
    out: Dict[int, List[int]] = {}
    for i, prompt in enumerate(prompts):
        cache = cache.allocate(i, cache.pages_for(len(prompt) + max_new))
        toks: List[int] = []
        for start in range(0, len(prompt), chunk):
            count = min(chunk, len(prompt) - start)
            buf = np.zeros((chunk,), np.int32)
            buf[:count] = np.asarray(prompt)[start:start + count]
            logits, cache = model.prefill_chunk(
                jnp.asarray(buf), count, i, start, cache
            )
        toks.append(int(np.argmax(np.asarray(logits)[: model.cfg.vocab])))
        out[i] = toks
    for _ in range(max_new - 1):
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for i in range(len(prompts)):
            tokens[i] = out[i][-1]
            active[i] = True
        logits, cache = model.decode_step(
            jnp.asarray(tokens), cache, jnp.asarray(active)
        )
        nxt = np.argmax(np.asarray(logits)[:, : model.cfg.vocab], axis=-1)
        for i in range(len(prompts)):
            out[i].append(int(nxt[i]))
    return out
