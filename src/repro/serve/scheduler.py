"""Continuous-batching request scheduler over AXI-Pack stream families.

The serving-side payoff of the paper's irregular streams: a fixed physical
resource pool, per-sequence descriptors as memory-resident index vectors,
and a scheduler that keeps the pool full of *useful* state.  Requests of
arbitrary length enter and leave mid-flight; every decode step is one
batched fused launch whose operands — and whose BASE-vs-PACK traffic
accounting — come from the family's own stream descriptors.

The scheduler is **family-agnostic**: it drives exactly one
:class:`repro.serve.family.ServableFamily` and speaks only the protocol —
resource *units* (pages for paged attention, state slots for recurrent
models), ``prefill_batch``/``decode_steps`` for compute,
``step_streams``/``prefill_account`` for accounting, and
``alloc_state``/``grow``/``release``/``replay`` for the lifecycle.  No
``isinstance`` check or KV-specific attribute appears below; the paged
transformer path (``repro.serve.paged_lm.PagedFamily``, indirect page-walk
streams) and the recurrent path (``repro.serve.recurrent_lm``, strided
state streams) run through the same code.

Scheduling policy (vLLM-shaped, deliberately simple and deterministic):

* **Admission** — priority/deadline ordered.  The queue sorts by
  ``(priority desc, absolute deadline asc, submission order)``; with the
  defaults (priority 0, no deadline) this is exactly FIFO.  The head of the
  queue is admitted when a batch slot is free and the pool holds units for
  its whole prompt plus one decode unit of headroom (head-of-line blocking
  is deliberate: it keeps admission deterministic and starvation-free).
  Prompt units are allocated at admission; decode units on demand.
  Requests that can *never* be served — worst-case units exceed the pool,
  or the prompt+generation exceeds the per-slot token capacity — are
  rejected at ``submit()`` with a typed, non-fatal :class:`RequestRejected`
  (reason ``NEVER_FITS``); a ``deadline_steps`` too tight to ever meet is
  rejected as ``DEADLINE_INFEASIBLE``; and a queued request whose deadline
  expires while the pool is busy is rejected as ``POOL_BUSY`` instead of
  being served late.  Rejection is a terminal state (``rejected``) tracked
  next to ``finished`` — it never poisons the scheduler.
* **Preemption** — when unit growth or admission hits pool exhaustion the
  scheduler evicts the resident with the *lowest priority*, tie-broken by
  the cheapest replay cost (prompt + generated tokens — exactly the work
  replay must redo), then by youth.  Each eviction charges the victim's
  ``replay_budget`` (tokens; ``None`` = unlimited); a victim whose budget
  is exhausted transitions to the terminal ``preempted`` state (partial
  output retained in ``generated``) instead of re-entering the queue.
* **Fault injection** — an optional :class:`repro.serve.faults.FaultPlan`
  drives chaos testing: forced pool exhaustion (admission/growth see zero
  free units), denied allocations (growth defers the starved request a
  step), prefix-index drops, and injected step latency fed to an optional
  ``StragglerWatchdog``.  Faults reroute through the same degradation
  ladder as real pressure — reclaim lookahead → drop retained prefixes →
  evict/preempt — and never raise out of ``run()``.  A fault action the
  family cannot express (a prefix drop against a family with no prefix
  index) no-ops with a counted skip (``stats.n_prefix_drop_skips``).
* **Prefill** — chunked and batched: each scheduler step advances *every*
  pending request by one fixed-size chunk in a single family
  ``prefill_batch`` call, interleaved with decode (prefill never starves
  decode and vice versa).  Each prefill step records the family's
  ``prefill_account`` descriptors the way decode steps record theirs.
* **Decode fast path** — between scheduling boundaries (admission, prefill,
  unit growth, retirement) every decode quantity is known on the host, so
  the scheduler *fuses* all steps up to the next boundary into one
  ``decode_steps`` call (device-resident sampling, pools donated in place)
  and syncs the token matrix back exactly once per boundary.  When nothing
  can be admitted or prefilled first, units for each request's remaining
  generation are preallocated from the free pool (lookahead never evicts),
  so growth stops being a boundary.  Per-step records come from the
  family's ``step_streams`` (host shadows only), so the PACK-vs-BASE
  accounting is unchanged from the step-at-a-time path.
* **Eviction** — when a decode step needs a unit and the pool is empty, the
  *youngest* resident request is preempted: its units return to the pool and
  it re-enters the queue front.  On re-admission ``replay(slot)`` resets
  the slot to what a fresh prefill assumes (a no-op for paged families;
  zeroed state rows for recurrent ones), its prompt is re-prefilled, and
  its previously generated tokens are *replayed through the decode path*
  (outputs discarded), which rebuilds its serving state bit-for-bit — so
  eviction is invisible in the output stream.  Replay inputs are forced
  from the recorded tokens at every fused-launch boundary; *within* a
  fused launch the device feeds its own greedy argmax, which matches the
  recorded tokens because the model is deterministic and row-wise (the
  property the equivalence tests assert) — a future nondeterministic
  kernel would have to cap fusion during replay.
* **Prefix sharing** (opt-in, ``prefix_sharing=True``; requires
  ``family.supports_prefix_sharing`` — token-granular refcounted units) —
  a :class:`PrefixIndex` maps page-aligned prompt chunks to the physical
  units that hold them.  Admission looks the new prompt up and maps every
  matched unit by refcount bump (``family.share``), prefilling only the
  divergent tail; completed prefills register their full prompt units, and
  retired requests' units are *retained* by the index (LRU) so later
  requests on the same system prompt hit the pool without it being
  resident.  Writes never land in a shared unit: admission privatizes the
  boundary unit up front via copy-on-write (``ensure_writable``), and the
  prefill/decode paths carry the same guard defensively.  Under pool
  pressure retained units are dropped LRU-first before any resident is
  evicted; eviction/replay re-derives shared mappings through the same
  lookup, so replay stays bit-for-bit (shared units are reused, never
  re-quantized differently in int8 mode).  Admission briefly *defers* a
  request whose prefix is still being prefilled by a resident sibling, so
  concurrent arrivals with one system prompt share it instead of each
  prefilling privately.
* **Hooks** — ``on_token(request, token)`` streams each newly generated
  token; ``on_finish(request)`` fires at completion.

Every decode step records a :class:`repro.core.packing.Traffic`: BASE is
the padded contiguous state a packing-oblivious server would stream, PACK
is the mapped units plus the near-memory descriptor fetch — connecting
serving throughput back to the Fig. 3 bus model.  The stream dialect is
the family's: :class:`repro.core.streams.IndirectStream` page walks for
paged KV (8-bit element width under int8 pools), strided read-modify-write
:class:`repro.core.streams.StridedStream` pairs for recurrent state.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import OrderedDict
from typing import (
    Any, Callable, Dict, FrozenSet, Iterator, List, Optional, Sequence,
    Tuple,
)

import numpy as np

from repro.core.packing import Traffic
from .family import ServableFamily
from .faults import FaultPlan

__all__ = [
    "PrefixIndex",
    "RejectReason",
    "Request",
    "RequestRejected",
    "RequestState",
    "Scheduler",
    "SchedulerStalledError",
    "StepRecord",
    "ServeStats",
    "build_prefill_rows",
]


class RejectReason(enum.Enum):
    """Why a request was rejected instead of served.

    * ``NEVER_FITS`` — the request's worst-case unit demand exceeds the
      pool, or its prompt+generation exceeds the per-slot token capacity;
      no amount of waiting can serve it.
    * ``POOL_BUSY`` — the request has a deadline, and by the time the busy
      pool could admit it the deadline can no longer be met.  With no
      deadline a request waits indefinitely instead.
    * ``DEADLINE_INFEASIBLE`` — the deadline is shorter than the minimum
      scheduler steps the request needs even on an idle pool.
    """

    NEVER_FITS = "never-fits"
    POOL_BUSY = "pool-busy"
    DEADLINE_INFEASIBLE = "deadline-infeasible"


class RequestRejected(RuntimeError):
    """Typed, non-fatal rejection: the scheduler stays fully consistent.

    Raised from ``submit(..., strict=True)`` (the default) so misuse is
    loud; with ``strict=False`` the rejection is recorded silently in
    ``Scheduler.rejected`` and submit returns ``False``.  Either way the
    request ends in the terminal ``REJECTED`` state with
    ``request.reject_reason`` set.
    """

    def __init__(self, request: "Request", reason: RejectReason, detail: str):
        super().__init__(
            f"request {request.rid} rejected ({reason.value}): {detail}"
        )
        self.request = request
        self.reason = reason


class SchedulerStalledError(RuntimeError):
    """``run()`` hit ``max_steps`` with work still pending.

    The message carries a full diagnostic dump — queue depth, free
    units/slots, and per-request state (rid, state, slot, prefill position,
    generated count, KV length, priority) — so a stall names the stuck
    request instead of leaving a context-free failure.
    """


class PrefixIndex:
    """Prompt-prefix → physical-unit index over page-aligned token chunks.

    Entry ``k`` of a prompt is keyed by the byte string of its first
    ``(k+1)·page`` tokens and maps to the physical page holding tokens
    ``[k·page, (k+1)·page)``.  Keying each page by the *cumulative* chunk
    (not just its own tokens) makes the mapping exact — two prompts share
    entry ``k`` iff they agree on every token up to that page boundary — so
    a lookup walk needs no verification pass and cannot alias.

    The index holds one refcount owner per registered page
    (``family.retain_units``), which is what keeps a retired prompt's
    prefix resident.  Entries are LRU-ordered; the scheduler drops them
    oldest-first under pool pressure.  Only meaningful for families with
    token-granular refcounted units (``supports_prefix_sharing``).
    """

    def __init__(self, page_size: int):
        self.page = page_size
        #: key → physical page id, in LRU order (oldest first).
        self.entries: "OrderedDict[bytes, int]" = OrderedDict()

    def chunks(self, prompt) -> Iterator[bytes]:
        """Cumulative page-aligned chunk keys of ``prompt``, in order."""
        pr = np.ascontiguousarray(np.asarray(prompt, dtype=np.int64))
        for k in range(len(pr) // self.page):
            yield pr[: (k + 1) * self.page].tobytes()

    def prefix_keys(self, prompt, n: int) -> FrozenSet[bytes]:
        """The first ``n`` chunk keys of ``prompt`` (a lookup's match set)."""
        out = []
        for k, key in enumerate(self.chunks(prompt)):
            if k >= n:
                break
            out.append(key)
        return frozenset(out)

    def match_len(self, prompt) -> int:
        """Longest indexed prefix of ``prompt``, in pages (LRU untouched)."""
        n = 0
        for key in self.chunks(prompt):
            if key not in self.entries:
                break
            n += 1
        return n

    def lookup(self, prompt) -> List[int]:
        """Physical pages of the longest indexed prefix; refreshes LRU."""
        ids: List[int] = []
        for key in self.chunks(prompt):
            page_id = self.entries.get(key)
            if page_id is None:
                break
            self.entries.move_to_end(key)
            ids.append(page_id)
        return ids

    def register(self, prompt, page_ids: Sequence[int]) -> List[int]:
        """Index ``prompt``'s full pages; returns the newly retained ones.

        Existing entries win (first prefill of a prefix is the canonical
        copy) — the caller must bump refcounts for exactly the returned
        pages.
        """
        new: List[int] = []
        for k, key in enumerate(self.chunks(prompt)):
            if k >= len(page_ids):
                break
            if key in self.entries:
                self.entries.move_to_end(key)
                continue
            self.entries[key] = int(page_ids[k])
            new.append(int(page_ids[k]))
        return new

    def pop_chain(self, key: bytes,
                  keep: FrozenSet[bytes] = frozenset()) -> List[int]:
        """Drop ``key`` and every entry extending it; returns their pages.

        Dropping the extensions keeps every remaining entry reachable from
        a fresh lookup walk (an entry whose ancestor is gone could never be
        matched again and would leak its retention).  ``keep`` protects a
        chain a pending admission has just matched.
        """
        pages: List[int] = []
        for k2 in [k for k in self.entries if k.startswith(key)]:
            if k2 in keep:
                continue
            pages.append(self.entries.pop(k2))
        return pages

    def pop_all(self) -> List[int]:
        """Drop every entry; returns all retained pages."""
        pages = list(self.entries.values())
        self.entries.clear()
        return pages


def build_prefill_rows(
    items: Sequence[Tuple[np.ndarray, int, int]], chunk: int, batch: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Assemble one batched-prefill call from pending (prompt, start, slot)s.

    Rows are pow2-bucketed to the pending count (never padded to the full
    batch): compute scales with actual prefill work while the jit cache
    stays O(log batch).  Returns ``(tokens (R, chunk), counts, slots,
    starts)`` with zero-filled padding rows past the pending set.  Single
    source of the bucketing/assembly shared by ``Scheduler._prefill_all``
    and the serving benchmark's isolated prefill phase — so the benchmark
    times exactly the calls the scheduler issues.
    """
    rows = min(1 << max(len(items) - 1, 0).bit_length(), batch)
    toks = np.zeros((rows, chunk), np.int32)
    counts = np.zeros((rows,), np.int32)
    slots = np.zeros((rows,), np.int32)
    starts = np.zeros((rows,), np.int32)
    for i, (prompt, start, slot) in enumerate(items):
        count = min(chunk, len(prompt) - start)
        toks[i, :count] = prompt[start:start + count]
        counts[i], slots[i], starts[i] = count, slot, start
    return toks, counts, slots, starts


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"
    FINISHED = "finished"    # terminal: completed max_new tokens
    PREEMPTED = "preempted"  # terminal: evicted with replay budget exhausted
    REJECTED = "rejected"    # terminal: never admitted (see RejectReason)


#: The states a request can end in — every submitted request reaches
#: exactly one of these (the chaos suite's terminal-accounting invariant).
TERMINAL_STATES = frozenset({
    RequestState.FINISHED, RequestState.PREEMPTED, RequestState.REJECTED,
})


@dataclasses.dataclass
class Request:
    """One generation request.

    ``generated`` includes every sampled token (the first comes from the
    prompt's last prefill logits).  ``fed`` counts decode inputs consumed
    since the last (re-)prefill: while ``fed + 1 < len(generated)`` the
    request is replaying after an eviction and decode outputs are discarded.

    SLA fields: ``priority`` orders admission and shields against
    preemption (higher wins; default 0).  ``deadline_steps`` bounds the
    scheduler steps from submission to completion — an infeasible deadline
    is rejected at submit, and one that expires while queued is rejected
    as pool-busy rather than served late.  ``replay_budget`` caps the total
    tokens (prompt + generated) this request may replay across evictions;
    exhausting it turns the next eviction into the terminal ``preempted``
    state with the partial output retained.
    """

    rid: int
    prompt: np.ndarray
    max_new: int
    priority: int = 0
    deadline_steps: Optional[int] = None
    replay_budget: Optional[int] = None
    on_token: Optional[Callable[["Request", int], None]] = None
    on_finish: Optional[Callable[["Request"], None]] = None

    state: RequestState = RequestState.WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    prefill_pos: int = 0      # prompt tokens already prefilled
    fed: int = 0              # decode inputs consumed since (re-)prefill
    n_evictions: int = 0
    admit_order: int = -1
    replay_spent: int = 0     # tokens charged against replay_budget so far
    submit_step: int = -1
    finish_step: int = -1
    reject_reason: Optional[RejectReason] = None
    _order: int = -1          # submission sequence (queue tie-break)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new

    @property
    def replaying(self) -> bool:
        return self.fed + 1 < len(self.generated)

    @property
    def replay_cost(self) -> int:
        """Tokens an eviction would force back through the model: the full
        prompt re-prefills and every generated-so-far token re-decodes."""
        return self.prompt_len + len(self.generated)

    @property
    def deadline_step(self) -> float:
        """Absolute step this request must finish by (inf if no deadline)."""
        if self.deadline_steps is None:
            return float("inf")
        return self.submit_step + self.deadline_steps


@dataclasses.dataclass
class StepRecord:
    """Per-model-step accounting (a fused launch emits one record per step).

    ``streams`` holds the family's descriptor dialect —
    ``IndirectStream`` page walks for paged KV, ``StridedStream``
    read-modify-write pairs for recurrent state.
    """

    step: int
    kind: str                 # 'decode' | 'verify' | 'prefill' | 'share'
    n_active: int
    new_tokens: int
    traffic: Optional[Traffic]
    streams: Tuple[Any, ...] = ()


@dataclasses.dataclass
class ServeStats:
    records: List[StepRecord] = dataclasses.field(default_factory=list)
    n_evictions: int = 0            # evict-and-requeue events (replayable)
    wall_s: float = 0.0
    prefill_tokens_saved: int = 0   # prompt tokens mapped instead of prefilled
    cow_copies: int = 0             # copy-on-write page copies performed
    n_preempted: int = 0            # terminal preemptions (budget exhausted)
    n_rejected: int = 0             # terminal rejections (any reason)
    reject_reasons: Dict[str, int] = dataclasses.field(default_factory=dict)
    deadline_misses: int = 0        # deadline requests rejected or late
    n_stragglers: int = 0           # watchdog-flagged slow steps
    n_prefix_drops: int = 0         # fault-injected prefix-index drops
    n_prefix_drop_skips: int = 0    # prefix-drop faults skipped (no index)
    # Speculative-decoding token accounting (kind='verify' records).  A
    # verify step *drafts* spec_k - 1 tokens per active slot, *accepts* the
    # matched prefix of them, and *emits* accepted + 1 bonus tokens into
    # request outputs (minus any dropped past a request's max_new).  Only
    # emitted tokens ever enter ``Request.generated`` — so ``replay_cost``
    # (and thus ``replay_budget`` charging) counts accepted work only,
    # never the drafts the verifier rejected.
    n_drafted: int = 0              # draft tokens proposed to the verifier
    n_accepted: int = 0             # draft tokens the verifier accepted
    n_emitted: int = 0              # tokens appended to request outputs

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verifier accepted."""
        return self.n_accepted / self.n_drafted if self.n_drafted else 0.0

    #: Decode-side record kinds: plain fused decode and speculative verify
    #: launches both stream the same per-step KV state, so the serving
    #: BASE/PACK aggregates fold them together.
    _DECODE_KINDS = ("decode", "verify")

    @property
    def decode_steps(self) -> int:
        return sum(1 for r in self.records if r.kind in self._DECODE_KINDS)

    @property
    def spec_steps(self) -> int:
        return sum(1 for r in self.records if r.kind == "verify")

    @property
    def tokens(self) -> int:
        return sum(r.new_tokens for r in self.records)

    def _sum(self, attr: str, kind=("decode", "verify")) -> int:
        kinds = (kind,) if isinstance(kind, str) else kind
        return sum(
            getattr(r.traffic, attr)
            for r in self.records
            if r.kind in kinds and r.traffic is not None
        )

    @property
    def base_bytes(self) -> int:
        return self._sum("base_bytes")

    @property
    def pack_bytes(self) -> int:
        return self._sum("pack_bytes") + self._sum("index_bus_bytes_pack")

    @property
    def useful_bytes(self) -> int:
        return self._sum("useful_bytes")

    @property
    def base_efficiency(self) -> float:
        return self.useful_bytes / self.base_bytes if self.base_bytes else 1.0

    @property
    def pack_efficiency(self) -> float:
        return self.useful_bytes / self.pack_bytes if self.pack_bytes else 1.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s else 0.0

    # -- prefill-side aggregates (same Traffic records, kind='prefill') ------

    @property
    def prefill_steps(self) -> int:
        return sum(1 for r in self.records if r.kind == "prefill")

    @property
    def prefill_base_bytes(self) -> int:
        return self._sum("base_bytes", "prefill")

    @property
    def prefill_pack_bytes(self) -> int:
        return (self._sum("pack_bytes", "prefill")
                + self._sum("index_bus_bytes_pack", "prefill"))

    @property
    def prefill_useful_bytes(self) -> int:
        return self._sum("useful_bytes", "prefill")

    @property
    def prefill_base_efficiency(self) -> float:
        b = self.prefill_base_bytes
        return self.prefill_useful_bytes / b if b else 1.0

    @property
    def prefill_pack_efficiency(self) -> float:
        p = self.prefill_pack_bytes
        return self.prefill_useful_bytes / p if p else 1.0

    # -- prefix-sharing aggregates (kind='share' records) --------------------

    @property
    def shared_pages(self) -> int:
        """Physical pages mapped by refcount bump instead of prefilled."""
        return sum(
            r.traffic.shared_pages
            for r in self.records
            if r.traffic is not None
        )

    @property
    def share_events(self) -> int:
        return sum(1 for r in self.records if r.kind == "share")

    @property
    def shared_useful_bytes(self) -> int:
        return self._sum("useful_bytes", "share")

    @property
    def shared_index_bytes(self) -> int:
        return self._sum("index_bus_bytes_pack", "share")

    @property
    def prefill_effective_pack_efficiency(self) -> float:
        """Prefill-side PACK efficiency with dedup folded in.

        Bytes of prompt KV the pool ends up serving (prefilled + shared)
        over the bytes PACK actually moved to get there (prefill payload
        and table fetches, plus the share remaps' table fetches).  Exceeds
        :attr:`prefill_pack_efficiency` exactly when prefix sharing elided
        prefill work — the dedup-before-packing multiplier; unlike a plain
        packing ratio it can exceed 1.
        """
        moved = (self.prefill_pack_bytes
                 + self._sum("pack_bytes", "share")
                 + self.shared_index_bytes)
        served = self.prefill_useful_bytes + self.shared_useful_bytes
        return served / moved if moved else 1.0


class Scheduler:
    """Continuous-batching scheduler driving one :class:`ServableFamily`.

    ``Scheduler(model, cache)`` binds the model to its resource pool via
    ``model.bind(cache)`` (every engine exposes it), so existing call
    sites keep working; an already-bound family can be passed directly as
    ``Scheduler(family)``.  The scheduler itself speaks only the protocol.
    """

    def __init__(self, model: Any, cache: Any = None, chunk: int = 8,
                 prefix_sharing: bool = False,
                 faults: Optional[FaultPlan] = None,
                 watchdog: Optional[Any] = None):
        if cache is not None:
            # May raise (e.g. the paged family's kv_dtype agreement check)
            # — binding validates model/pool compatibility.
            family: ServableFamily = model.bind(cache)
        elif isinstance(model, ServableFamily):
            family = model
        else:
            raise TypeError(
                "Scheduler needs a ServableFamily, or a model plus the "
                "cache/pool to bind one"
            )
        if prefix_sharing and not family.supports_prefix_sharing:
            raise ValueError("prefix_sharing requires a refcounted cache")
        self.family = family
        #: The family's underlying model (compat accessor; never used by
        #: scheduling logic).
        self.model = getattr(family, "model", model)
        self.chunk = chunk
        self.prefix_index: Optional[PrefixIndex] = (
            PrefixIndex(family.page_size) if prefix_sharing else None
        )
        #: Injected fault schedule (chaos testing); None = fault-free.
        self.faults = faults
        #: Anything with ``observe(dt, injected=...) -> bool`` — typically a
        #: :class:`repro.runtime.fault_tolerance.StragglerWatchdog`.
        self.watchdog = watchdog
        #: Priority/deadline-ordered wait queue (head = next to admit).
        self.queue: List[Request] = []
        self.resident: List[Request] = []      # admission order
        self.finished: Dict[int, Request] = {}
        self.preempted: Dict[int, Request] = {}  # terminal: budget exhausted
        self.rejected: Dict[int, Request] = {}   # terminal: never admitted
        self.stats = ServeStats()
        self._step = 0
        self._admit_counter = 0
        self._submit_counter = 0
        self._free_slots = list(range(family.batch))[::-1]

    @property
    def cache(self):
        """The family's underlying resource pool (page pool / state pool).

        Compatibility accessor for tests, benchmarks, and diagnostics; the
        scheduling logic itself never reaches through it.
        """
        return getattr(self.family, "cache", None)

    # -- public API ---------------------------------------------------------

    @staticmethod
    def _max_kv(request: Request) -> int:
        # The last generated token is never fed back, so KV peaks one short.
        return request.prompt_len + max(request.max_new - 1, 0)

    def _min_steps(self, request: Request) -> int:
        """Minimum scheduler steps from admission to completion: one per
        prefill chunk (the last emits the first token), plus one decode
        boundary when more tokens remain (fusion covers any length)."""
        prefill = -(-request.prompt_len // self.chunk)
        return prefill + (1 if request.max_new > 1 else 0)

    def _queue_key(self, r: Request) -> Tuple[int, float, int]:
        """Admission order: priority desc, deadline asc, submission order.

        An evicted request keeps its original ``_order``, so it re-enters
        ahead of later arrivals of equal priority — the behaviour the old
        FIFO ``appendleft`` re-queue had.
        """
        return (-r.priority, r.deadline_step, r._order)

    def _queue_push(self, r: Request) -> None:
        self.queue.append(r)
        self.queue.sort(key=self._queue_key)  # stable; queues are small

    def _reject(self, request: Request, reason: RejectReason, detail: str,
                strict: bool) -> bool:
        """Move ``request`` to the terminal REJECTED state (non-fatal)."""
        request.state = RequestState.REJECTED
        request.reject_reason = reason
        request.finish_step = self._step
        self.rejected[request.rid] = request
        self.stats.n_rejected += 1
        self.stats.reject_reasons[reason.value] = (
            self.stats.reject_reasons.get(reason.value, 0) + 1
        )
        if request.deadline_steps is not None:
            self.stats.deadline_misses += 1
        if strict:
            raise RequestRejected(request, reason, detail)
        return False

    def submit(self, request: Request, strict: bool = True) -> bool:
        """Queue a request, or reject it with a typed, non-fatal reason.

        Returns ``True`` when queued.  A request that can never be served
        (``NEVER_FITS``) or whose deadline is impossible even on an idle
        pool (``DEADLINE_INFEASIBLE``) goes straight to the terminal
        ``REJECTED`` state; with ``strict=True`` (default) a
        :class:`RequestRejected` is also raised so misuse is loud, with
        ``strict=False`` submit just returns ``False``.  Either way the
        scheduler remains fully consistent — rejection is bookkeeping, not
        a failure.
        """
        if request.max_new < 1:
            raise ValueError(
                f"request {request.rid}: max_new must be >= 1"
            )
        request.submit_step = self._step
        worst = self.family.units_for(self._max_kv(request))
        if worst > self.family.total_units:
            return self._reject(
                request, RejectReason.NEVER_FITS,
                f"needs up to {worst} pages; the pool holds "
                f"{self.family.total_units}", strict,
            )
        if self._max_kv(request) > self.family.slot_token_capacity:
            return self._reject(
                request, RejectReason.NEVER_FITS,
                f"prompt+generation ({self._max_kv(request)} tokens) exceeds "
                f"the {self.family.slot_token_capacity}-token slot capacity",
                strict,
            )
        if (request.deadline_steps is not None
                and request.deadline_steps < self._min_steps(request)):
            return self._reject(
                request, RejectReason.DEADLINE_INFEASIBLE,
                f"deadline of {request.deadline_steps} steps is below the "
                f"{self._min_steps(request)}-step minimum", strict,
            )
        request.state = RequestState.WAITING
        request._order = self._submit_counter
        self._submit_counter += 1
        self._queue_push(request)
        return True

    def _stall_report(self, max_steps: int) -> str:
        """Diagnostic dump for SchedulerStalledError: names every stuck
        request with enough state to see *why* it is stuck."""
        lens = self._lengths()
        lines = [
            f"scheduler stalled after {max_steps} steps: "
            f"{len(self.queue)} queued, {len(self.resident)} resident, "
            f"{self.family.free_units}/{self.family.total_units} pages free, "
            f"{len(self._free_slots)} slots free",
        ]
        for r in list(self.resident) + list(self.queue):
            kv = int(lens[r.slot]) if r.slot >= 0 else 0
            lines.append(
                f"  request {r.rid}: state={r.state.value} slot={r.slot} "
                f"prefill_pos={r.prefill_pos}/{r.prompt_len} "
                f"generated={len(r.generated)}/{r.max_new} kv_len={kv} "
                f"priority={r.priority} evictions={r.n_evictions}"
            )
        return "\n".join(lines)

    def run(self, max_steps: int = 100_000) -> Dict[int, List[int]]:
        """Drive all submitted requests to a terminal state.

        Returns the completed outputs (``finished`` only); preempted and
        rejected requests are tracked in :attr:`preempted` /
        :attr:`rejected` with their partial state.  Raises
        :class:`SchedulerStalledError` — with a full per-request dump —
        if work is still pending after ``max_steps``.
        """
        t0 = time.perf_counter()
        while (self.queue or self.resident) and self._step < max_steps:
            self.step()
        self.stats.wall_s += time.perf_counter() - t0
        if self.queue or self.resident:
            raise SchedulerStalledError(self._stall_report(max_steps))
        return {rid: r.generated for rid, r in sorted(self.finished.items())}

    def step(self) -> None:
        """One scheduler iteration: expire deadlines → admit → one batched
        prefill chunk → fused decode to the next scheduling boundary →
        retire.  Injected faults (``self.faults``) apply for the duration
        of the step; its wall time (plus any injected latency) feeds the
        watchdog."""
        self._step += 1
        t0 = time.perf_counter()
        if self.faults is not None and self.faults.drop_prefix(self._step):
            if self.prefix_index is None:
                # The fault action doesn't apply to this family/config (no
                # prefix index to drop): counted no-op, never a raise.
                self.stats.n_prefix_drop_skips += 1
            else:
                self._drop_prefix_fault()
        self._expire_deadlines()
        self._admit()
        self._prefill_all()
        self._decode()
        self._retire()
        if self.watchdog is not None:
            injected = (self.faults.delay(self._step)
                        if self.faults is not None else 0.0)
            if self.watchdog.observe(time.perf_counter() - t0,
                                     injected=injected):
                self.stats.n_stragglers += 1

    # -- fault hooks ---------------------------------------------------------

    def _effective_free(self) -> int:
        """Free units as scheduling policy sees them: zero while a forced
        pool-exhaustion fault is active (the physical free list is
        untouched — CoW and already-checked admissions still succeed)."""
        if self.faults is not None and self.faults.exhaust(self._step):
            return 0
        return self.family.free_units

    def _alloc_denied(self) -> bool:
        return (self.faults is not None
                and self.faults.deny_alloc(self._step))

    def _drop_prefix_fault(self) -> None:
        """Fault: drop one seeded-random retained prefix chain.  Sharing is
        an optimization, so victims of the drop simply re-prefill — the
        chaos suite asserts outputs are unchanged."""
        entries = list(self.prefix_index.entries)
        if not entries:
            return
        rng = np.random.default_rng([self.faults.seed, self._step])
        key = entries[int(rng.integers(len(entries)))]
        pages = self.prefix_index.pop_chain(key)
        self.family.release_units(pages)
        self.stats.n_prefix_drops += 1

    def _expire_deadlines(self) -> None:
        """Reject queued requests whose deadline can no longer be met.

        Admitted even *this* step, a request finishes no earlier than
        ``_step + _min_steps - 1``; when that overshoots the deadline the
        request is rejected as POOL_BUSY rather than served late.  Resident
        requests are never killed by a deadline — they finish and count a
        deadline miss instead (killing mid-flight work would waste the
        units it already filled).
        """
        expired = [
            r for r in self.queue
            if r.deadline_steps is not None
            and self._step + self._min_steps(r) - 1 > r.deadline_step
        ]
        for r in expired:
            self.queue.remove(r)
            self._reject(
                r, RejectReason.POOL_BUSY,
                f"deadline at step {int(r.deadline_step)} can no longer be "
                f"met at step {self._step}", strict=False,
            )

    # -- host shadow state ---------------------------------------------------

    def _lengths(self) -> np.ndarray:
        """Per-slot token lengths (family host shadow; no device sync)."""
        return self.family.lengths()

    # -- admission ----------------------------------------------------------

    def _reclaim_lookahead(self, need: int) -> None:
        """Trim residents' unwritten lookahead units back to the free pool.

        Lookahead prealloc (see ``_grow_units``) may have mapped units for
        generations that have not happened yet; those units hold no state,
        so reclaiming them for an admission is loss-free — the residents
        simply fall back to on-demand growth.  Trims youngest-first, down
        to each request's written content (prompt units for a request
        still in prefill)."""
        for r in sorted(self.resident, key=lambda x: -x.admit_order):
            if self._effective_free() >= need:
                return
            if r.state is RequestState.PREFILL:
                floor = self.family.units_for(r.prompt_len)
            else:
                floor = self.family.units_for(int(self._lengths()[r.slot]))
            self.family.trim(r.slot, floor)

    def _drop_retained(self, need: int,
                       keep: FrozenSet[bytes] = frozenset()) -> None:
        """Release retained prefix entries (LRU-first) until ``need`` free.

        An entry whose page is still shared with a resident frees nothing
        when dropped, so it is skipped; ``keep`` protects the chain a
        pending admission has just matched.  Dropping an entry drops its
        whole extension chain (see :meth:`PrefixIndex.pop_chain`).
        """
        if self.prefix_index is None:
            return
        for key in list(self.prefix_index.entries):
            if self._effective_free() >= need:
                return
            if key not in self.prefix_index.entries or key in keep:
                continue  # already popped as part of an earlier chain
            page_id = self.prefix_index.entries[key]
            if self.family.unit_refcount(page_id) > 1:
                continue
            pages = self.prefix_index.pop_chain(key, keep=keep)
            self.family.release_units(pages)

    def flush_prefix_cache(self) -> None:
        """Drop every retained prefix entry; unshared pages return to free."""
        if self.prefix_index is None:
            return
        self.family.release_units(self.prefix_index.pop_all())

    def _defer_for_inflight_prefix(self, r: Request) -> bool:
        """Hold admission while a still-prefilling resident is building a
        longer shared prefix for ``r`` than the index already offers.

        Registration happens at prefill completion, so concurrent arrivals
        with a common system prompt would otherwise each prefill it
        privately; waiting one scheduling boundary converts the later ones
        into refcount bumps.  Terminates because prefill advances every
        pending resident each step: the sibling either completes (and
        registers at least the pages counted here) or is evicted (and the
        defer condition vanishes).
        """
        assert self.prefix_index is not None
        page = self.family.page_size
        pr = np.asarray(r.prompt, dtype=np.int64)
        have = self.prefix_index.match_len(r.prompt)
        for s in self.resident:
            if s.state is not RequestState.PREFILL:
                continue
            ps = np.asarray(s.prompt, dtype=np.int64)
            limit = min(len(pr), (s.prompt_len // page) * page) // page
            n = 0
            while (n < limit and np.array_equal(
                    pr[n * page:(n + 1) * page], ps[n * page:(n + 1) * page])):
                n += 1
            if n > have:
                return True
        return False

    def _admit(self) -> None:
        while self.queue and self._free_slots:
            r = self.queue[0]
            shared: List[int] = []
            if self.prefix_index is not None:
                if self._defer_for_inflight_prefix(r):
                    return
                shared = self.prefix_index.lookup(r.prompt)
            page = self.family.page_size
            shared_tokens = len(shared) * page
            # Admission always (re-)prefills at least the prompt's last
            # token, so completing prefill yields fresh last-token logits.
            # A fully page-aligned match therefore writes one token into
            # its final *shared* page — privatized eagerly below via
            # copy-on-write, with the extra unit counted in ``need`` so two
            # same-step admissions can't both claim the same free unit.
            tail_start = min(shared_tokens, r.prompt_len - 1)
            cow_extra = 1 if shared_tokens > tail_start else 0
            # Units for the whole prompt, plus one decode unit of headroom
            # when the first appended token will cross a unit boundary.
            need = (self.family.units_for(
                min(r.prompt_len + 1, self._max_kv(r))
            ) - len(shared) + cow_extra)
            if need > 0 and self._alloc_denied():
                return  # fault: allocations fail this step; retry next step
            if self._effective_free() < need:
                self._reclaim_lookahead(need)
            if self._effective_free() < need and self.prefix_index is not None:
                self._drop_retained(
                    need,
                    keep=self.prefix_index.prefix_keys(r.prompt, len(shared)),
                )
            if self._effective_free() < need:
                return
            self.queue.pop(0)
            r.slot = self._free_slots.pop()
            r.state = RequestState.PREFILL
            r.prefill_pos = tail_start
            r.fed = 0
            r.admit_order = self._admit_counter
            self._admit_counter += 1
            if shared:
                self.family.share(r.slot, shared)
            fresh = self.family.units_for(r.prompt_len) - len(shared)
            if fresh > 0:
                self.family.alloc_state(r.slot, fresh)
            if cow_extra:
                self.stats.cow_copies += self.family.ensure_writable(
                    r.slot, tail_start, tail_start
                )
            # Reset the slot to fresh-prefill state: a no-op for paged
            # families (new pages are empty), a state-row zero for
            # recurrent ones — the half of eviction-replay that lives in
            # device state rather than in the token bookkeeping.
            self.family.replay(r.slot)
            if shared:
                # Replay after eviction walks this same path: the lookup
                # re-derives the mappings, so re-admission reuses the pages
                # (bit-identical KV, int8 scales included) it had before.
                self.stats.prefill_tokens_saved += tail_start
                traffic, streams = self.family.share_account(
                    tail_start, shared
                )
                self.stats.records.append(StepRecord(
                    step=self._step, kind="share", n_active=1, new_tokens=0,
                    traffic=traffic, streams=streams,
                ))
            self.resident.append(r)

    # -- prefill ------------------------------------------------------------

    def _prefill_all(self) -> None:
        """One chunk for *every* pending request, in one batched call."""
        pending = [r for r in self.resident if r.state is RequestState.PREFILL]
        if not pending:
            return
        pending.sort(key=lambda x: x.admit_order)
        toks, counts, slots, starts = build_prefill_rows(
            [(r.prompt, r.prefill_pos, r.slot) for r in pending],
            self.chunk, self.family.batch,
        )
        if self.prefix_index is not None:
            # Defensive: admission privatizes the only shared page a prefill
            # can write (the page-aligned-match boundary), so this is a
            # refcount scan that never copies — unless an invariant broke,
            # in which case copy-on-write still keeps siblings isolated.
            for i, r in enumerate(pending):
                self.stats.cow_copies += self.family.ensure_writable(
                    r.slot, int(starts[i]), int(starts[i] + counts[i]) - 1
                )
        logits = self.family.prefill_batch(toks, counts, slots, starts)
        new_tokens = 0
        completed = []
        for i, r in enumerate(pending):
            r.prefill_pos += int(counts[i])
            if r.prefill_pos == r.prompt_len:
                r.state = RequestState.RUNNING
                r.fed = 0
                if not r.generated:  # fresh prefill; a replay already has it
                    completed.append((i, r))
                if self.prefix_index is not None:
                    # Register the full prompt pages (the partial last page,
                    # which decode will keep writing, is never indexed) and
                    # give the index its refcount owner on the new entries.
                    n_full = r.prompt_len // self.family.page_size
                    new_pages = self.prefix_index.register(
                        r.prompt, self.family.slot_unit_ids(r.slot)[:n_full]
                    )
                    self.family.retain_units(new_pages)
        if completed:
            lg = np.asarray(logits)  # host sync: admission boundary only
            for i, r in completed:
                tok = int(np.argmax(lg[i, : self.family.vocab]))
                r.generated.append(tok)
                new_tokens += 1
                if r.on_token:
                    r.on_token(r, tok)
        # Stream descriptors + traffic in the family's own dialect, from
        # the same host-shadow math its kernels resolve (as decode does).
        n = len(pending)
        traffic, streams = self.family.prefill_account(
            slots[:n], starts[:n], counts[:n]
        )
        self.stats.records.append(StepRecord(
            step=self._step, kind="prefill", n_active=n,
            new_tokens=new_tokens, traffic=traffic, streams=streams,
        ))

    # -- decode -------------------------------------------------------------

    def _fused_steps(self, running: List[Request]) -> int:
        """Decode steps until the next scheduling boundary.

        Between boundaries nothing the scheduler decides on can change: the
        running set is fixed (retirement is a boundary), unit mappings are
        fixed (growth is a boundary), and admission cannot unblock (slots
        and units free up only at boundaries).  While any resident is still
        prefilling we keep single steps so prefill stays interleaved.
        """
        if any(r.state is RequestState.PREFILL for r in self.resident):
            return 1
        k = self.family.spec_k
        lens = self._lengths()
        # With speculation each launch step consumes up to ``k`` feed
        # tokens and writes up to ``k`` KV entries, so both horizons are
        # divided by ``k``: ceil for completion (the in-graph capacity
        # clamp plus the host-side done-drop make a partial final step
        # safe), floor for growth (a step with under ``k`` tokens of
        # headroom still progresses — the clamp scores what fits).
        to_done = min(-(-(r.max_new - 1 - r.fed) // k) for r in running)
        to_growth = min(
            (self.family.token_capacity(r.slot) - int(lens[r.slot])) // k
            for r in running
        )
        return max(1, min(to_done, to_growth))

    def _decode(self) -> None:
        running = [
            r for r in self.resident
            if r.state is RequestState.RUNNING and not r.done
        ]
        if not running:
            return
        running = self._grow_units(running)
        if not running:
            return
        b = self.family.batch
        tokens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for r in running:
            tokens[r.slot] = r.generated[r.fed]
            active[r.slot] = True

        # Fuse up to the boundary: device-resident scan chunks, one token
        # sync at the end (the scheduling boundary).
        k = self.family.spec_k
        n = self._fused_steps(running)
        if self.prefix_index is not None:
            # Defensive: decode appends land past the prompt, and shared
            # pages only ever cover full prompt pages, so this scan never
            # copies unless an invariant broke (see _prefill_all).
            lens_cow = self._lengths()
            for r in running:
                ln = int(lens_cow[r.slot])
                self.stats.cow_copies += self.family.ensure_writable(
                    r.slot, ln, ln + n * k - 1
                )
        if k > 1:
            self._decode_speculative(running, tokens, active, n)
            return
        # Per-step accounting snapshots come *before* the launch mutates the
        # family's host shadows — identical records to a step-at-a-time run.
        accounts = self.family.step_streams(active, n)
        out = self.family.decode_steps(tokens, active, n)

        for s in range(n):
            traffic, streams = accounts[s]
            new_tokens = 0
            for r in running:
                r.fed += 1
                if r.fed < len(r.generated):
                    continue  # replay after eviction: output already known
                tok = int(out[s, r.slot])
                r.generated.append(tok)
                new_tokens += 1
                if r.on_token:
                    r.on_token(r, tok)
            self.stats.records.append(StepRecord(
                step=self._step, kind="decode", n_active=len(running),
                new_tokens=new_tokens, traffic=traffic, streams=streams,
            ))

    def _decode_speculative(self, running: List[Request],
                            tokens: np.ndarray, active: np.ndarray,
                            n: int) -> None:
        """Speculative counterpart of the plain fused-decode tail.

        One ``verify_steps`` launch covers ``n`` draft→verify→accept
        iterations; the emitted tokens per (step, slot) are data-dependent,
        so traffic accounting runs *after* the launch from the pre-launch
        length shadow (``verify_account``), and the host consumption loop
        walks ``counts[s, slot]`` emissions instead of exactly one.  A
        request that completes mid-launch simply drops the surplus
        emissions (the device kept verifying its own greedy continuation;
        the extra KV dies with the slot at retirement).  Replay is the
        plain-decode story unchanged: emitted tokens are the greedy
        sequence, so re-fed requests consume recorded tokens until
        ``fed`` catches up with ``generated``.
        """
        k = self.family.spec_k
        lens0 = np.array(self._lengths(), copy=True)
        toks, counts = self.family.verify_steps(tokens, active, n)
        accounts = self.family.verify_account(lens0, active, counts)
        for s in range(n):
            traffic, streams = accounts[s]
            new_tokens = 0
            for r in running:
                c = int(counts[s, r.slot])
                self.stats.n_drafted += k - 1
                self.stats.n_accepted += max(c - 1, 0)
                for i in range(c):
                    if r.done:
                        break  # surplus emissions past max_new: dropped
                    r.fed += 1
                    if r.fed < len(r.generated):
                        continue  # replay after eviction: output known
                    tok = int(toks[s, r.slot, i])
                    r.generated.append(tok)
                    new_tokens += 1
                    self.stats.n_emitted += 1
                    if r.on_token:
                        r.on_token(r, tok)
            self.stats.records.append(StepRecord(
                step=self._step, kind="verify", n_active=len(running),
                new_tokens=new_tokens, traffic=traffic, streams=streams,
            ))

    def _grow_units(self, running: List[Request]) -> List[Request]:
        """Allocate a unit for every running request whose next token lands
        past its slot's capacity, evicting the cheapest low-priority resident
        when the pool runs dry (the requester itself defers when it *is* the
        victim).  Returns the requests that still run this step.  Families
        whose slots never grow (recurrent state) report unbounded capacity,
        so this is pure pass-through for them."""
        lengths = self._lengths()
        spec_k = self.family.spec_k
        deferred: set = set()
        for r in sorted(running, key=lambda x: x.admit_order):
            if r.state is not RequestState.RUNNING:
                continue  # evicted below by another request's allocation
            # Headroom this step needs: one token for plain decode, up to
            # ``spec_k`` for a speculative family (capped by the tokens the
            # request can still feed — the last verify step never needs
            # room past its final emission).  With spec_k == 1 this is
            # exactly the old ``lengths == capacity`` growth trigger.
            head = min(spec_k, max(r.max_new - 1 - r.fed, 1))
            target = int(lengths[r.slot]) + head
            while (r.state is RequestState.RUNNING
                   and self.family.token_capacity(r.slot) < target):
                if self._alloc_denied():
                    # Fault: allocations fail this step.  The request keeps
                    # its slot and units; with zero headroom it sits out
                    # this step's decode (growth retried next boundary),
                    # with partial headroom the capacity clamp lets it run
                    # short.  Nothing was mutated, so the pool stays
                    # consistent (the crash-consistency contract).
                    if (self.family.token_capacity(r.slot)
                            <= int(lengths[r.slot])):
                        deferred.add(r.rid)
                    break
                while (r.state is RequestState.RUNNING
                       and self._effective_free() < 1):
                    # Retained-but-unshared prefix pages are the cheapest
                    # relief (no resident loses work); then evict the
                    # lowest-priority resident with the cheapest replay
                    # (youngest on ties).  Each iteration frees a unit,
                    # removes a resident, or empties the index, so the loop
                    # terminates.
                    self._drop_retained(1)
                    if self._effective_free() >= 1:
                        break
                    victim = min(
                        self.resident,
                        key=lambda x: (
                            x.priority, x.replay_cost, -x.admit_order
                        ),
                    )
                    if victim is r and len(self.resident) == 1:
                        if (self.prefix_index is not None
                                and self.prefix_index.entries):
                            # Last resort: drop retention even for pages
                            # this request shares — it keeps its own
                            # mappings.
                            self.flush_prefix_cache()
                            continue
                        # Pool truly (or by injected fault) cannot grow the
                        # only resident: it defers by self-eviction —
                        # requeued for replay, or preempted when its budget
                        # is spent.  Never an exception out of run().
                        self._evict(r)
                        break
                    self._evict(victim)  # may be r: it defers, not others
                if r.state is not RequestState.RUNNING:
                    break
                if not self.family.grow(r.slot, 1):
                    if (self.family.token_capacity(r.slot)
                            <= int(lengths[r.slot])):
                        deferred.add(r.rid)
                    break
        still = [
            r for r in running
            if r.state is RequestState.RUNNING and r.rid not in deferred
        ]
        # Opportunistic lookahead: when nothing can be admitted or prefilled
        # before the next boundary AND the free pool covers *every* running
        # request's full remaining generation, map those units up front, so
        # growth stops being a scheduling boundary and decode fuses through.
        # The all-or-nothing condition means lookahead can never starve a
        # peer's imminent on-demand growth (no extra evictions versus the
        # on-demand policy); under pool pressure it simply stays off and
        # behaviour is exactly the on-demand path.
        if not self.queue and not self._alloc_denied() and not any(
            x.state is RequestState.PREFILL for x in self.resident
        ):
            lens = self._lengths()
            # Speculative families over-write by up to spec_k - 1 KV
            # entries past the final emission (the clamp would otherwise
            # shorten the last verify steps and reintroduce growth
            # boundaries), so lookahead maps that margin too — capped at
            # the slot's hard token capacity.
            wants = {
                r.rid: (self.family.units_for(min(
                    int(lens[r.slot]) + (r.max_new - 1 - r.fed)
                    + (spec_k - 1),
                    self.family.slot_token_capacity,
                )) - self.family.mapped_units(r.slot))
                for r in still
            }
            if sum(max(w, 0) for w in wants.values()) <= self._effective_free():
                for r in sorted(still, key=lambda x: x.admit_order):
                    if wants[r.rid] > 0:
                        self.family.alloc_state(r.slot, wants[r.rid])
        return still

    def _evict(self, r: Request) -> None:
        """Release ``r``'s units and slot, then requeue it for bit-identical
        replay — unless replaying it would blow its ``replay_budget``, in
        which case it lands in the terminal PREEMPTED state with its partial
        output intact."""
        cost = r.replay_cost  # before release: prompt + tokens to re-derive
        self.family.release(r.slot)
        self.resident.remove(r)
        self._free_slots.append(r.slot)
        r.slot = -1
        r.prefill_pos = 0
        r.fed = 0
        if (r.replay_budget is not None
                and r.replay_spent + cost > r.replay_budget):
            r.state = RequestState.PREEMPTED
            r.finish_step = self._step
            self.preempted[r.rid] = r
            self.stats.n_preempted += 1
            if r.deadline_steps is not None:
                self.stats.deadline_misses += 1
            return
        r.replay_spent += cost
        r.state = RequestState.WAITING
        r.n_evictions += 1
        self.stats.n_evictions += 1
        # Keeps its original submission order, so among equal priorities it
        # re-admits first — the FIFO fairness the old appendleft gave.
        self._queue_push(r)

    # -- retirement ---------------------------------------------------------

    def _retire(self) -> None:
        for r in [x for x in self.resident if x.done]:
            self.family.release(r.slot)
            self.resident.remove(r)
            self._free_slots.append(r.slot)
            r.slot = -1
            r.state = RequestState.FINISHED
            r.finish_step = self._step
            if (r.deadline_steps is not None
                    and r.finish_step > r.deadline_step):
                self.stats.deadline_misses += 1
            self.finished[r.rid] = r
            if r.on_finish:
                r.on_finish(r)
