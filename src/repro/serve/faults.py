"""Chaos layer for the serving stack: seeded fault plans + invariant checker.

The scheduler's robustness contract is that contention and faults degrade
service instead of crashing it: pool exhaustion turns into eviction /
preemption / typed rejection, never an exception out of ``run()``.  This
module provides the two tools that lock that contract down:

* :class:`FaultPlan` — a deterministic, seeded schedule of injected faults
  the :class:`repro.serve.Scheduler` consults each step:

  - **forced pool exhaustion** (``exhaust_at``): the scheduler's admission
    and page-growth policy sees zero free pages even though the physical
    free list is intact, driving the reclaim → drop-retained → evict →
    preempt ladder under full pressure;
  - **denied allocations** (``deny_alloc_at``): page allocations fail for
    the step (the mid-flight ``OutOfPages`` scenario) — growth defers the
    starved request to the next step instead of raising;
  - **prefix-index drops** (``drop_prefix_at``): a retained prefix chain is
    dropped from the :class:`repro.serve.PrefixIndex`, exercising the
    re-prefill path (outputs must not change — sharing is an optimization);
  - **injected step latency** (``delay_at``): extra seconds added to the
    observed step wall time and fed to the
    :class:`repro.runtime.fault_tolerance.StragglerWatchdog`, so slow-host
    detection is testable without sleeping.

  Plans are finite: no fault fires past :attr:`FaultPlan.horizon`, which is
  what guarantees liveness (every request reaches a terminal state once the
  chaos window closes).  :meth:`FaultPlan.random` derives a plan purely
  from ``(seed, n_steps, intensities)`` so chaos runs replay bit-for-bit.

* :func:`check_scheduler_invariants` — the step-wise consistency oracle
  chaos tests assert after *every* scheduler step: pool self-consistency
  (via the family's ``check_integrity`` — free/owned partition and
  refcount conservation for paged pools, slot-ownership partition for
  recurrent state pools), slot bookkeeping, no orphaned host shadows, and
  every request in exactly one live or terminal bucket (``done`` /
  ``preempted`` / ``rejected``).  The checker speaks only the
  :class:`repro.serve.family.ServableFamily` protocol, so one oracle
  covers every model family the scheduler serves.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Mapping, Optional, Sequence

import numpy as np

__all__ = [
    "FaultPlan",
    "InvariantViolation",
    "check_scheduler_invariants",
    "terminal_states",
]


class InvariantViolation(AssertionError):
    """A scheduler/pool consistency invariant does not hold."""


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise InvariantViolation(msg)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic per-step fault schedule (steps are 1-indexed, matching
    ``Scheduler._step``).  All fields are explicit so a failing chaos run's
    plan can be printed and replayed verbatim."""

    seed: int = 0
    exhaust_at: FrozenSet[int] = frozenset()
    deny_alloc_at: FrozenSet[int] = frozenset()
    drop_prefix_at: FrozenSet[int] = frozenset()
    delay_at: Mapping[int, float] = dataclasses.field(default_factory=dict)

    # -- queries (the scheduler's per-step hooks) ---------------------------

    def exhaust(self, step: int) -> bool:
        """Admission/growth must treat the free pool as empty this step."""
        return step in self.exhaust_at

    def deny_alloc(self, step: int) -> bool:
        """Page allocations fail this step (growth defers, never raises)."""
        return step in self.deny_alloc_at

    def drop_prefix(self, step: int) -> bool:
        """Drop a retained prefix chain at the top of this step."""
        return step in self.drop_prefix_at

    def delay(self, step: int) -> float:
        """Injected wall seconds added to this step's observed time."""
        return float(self.delay_at.get(step, 0.0))

    @property
    def horizon(self) -> int:
        """Last step any fault fires; the liveness bound for chaos runs."""
        steps = (set(self.exhaust_at) | set(self.deny_alloc_at)
                 | set(self.drop_prefix_at) | set(self.delay_at))
        return max(steps) if steps else 0

    # -- constructors -------------------------------------------------------

    @classmethod
    def none(cls) -> "FaultPlan":
        return cls()

    @classmethod
    def random(cls, seed: int, n_steps: int = 24, p_exhaust: float = 0.2,
               p_deny: float = 0.15, p_drop: float = 0.1,
               p_delay: float = 0.0, delay_s: float = 0.05) -> "FaultPlan":
        """A seeded plan over scheduler steps ``1..n_steps``.

        Each fault class fires independently per step with its probability;
        past ``n_steps`` the plan is silent, so a random plan always has a
        finite horizon.  The same ``(seed, n_steps, p_*)`` always yields
        the same plan.
        """
        rng = np.random.default_rng(seed)

        def pick(p: float) -> FrozenSet[int]:
            draws = rng.random(n_steps)
            return frozenset(int(s) + 1 for s in np.nonzero(draws < p)[0])

        exhaust = pick(p_exhaust)
        deny = pick(p_deny)
        drop = pick(p_drop)
        delays = {s: delay_s for s in pick(p_delay)}
        return cls(seed=seed, exhaust_at=exhaust, deny_alloc_at=deny,
                   drop_prefix_at=drop, delay_at=delays)


def check_scheduler_invariants(sched, requests: Optional[Sequence] = None,
                               ) -> None:
    """Assert the scheduler + pool consistency invariants; raise
    :class:`InvariantViolation` on the first breach.

    Checked after every step in the chaos suites (and usable anywhere — it
    reads only host-side state, never syncing the device):

    1. **Pool integrity** — the family's own ``check_integrity``:
       free/owned partition and refcount conservation against table
       mappings + prefix retentions for paged pools; slot-ownership
       partition for recurrent state pools.
    2. **Slot bookkeeping** — resident slots are distinct, and together
       with the free-slot stack they partition the batch.
    3. **State discipline** — queued requests are WAITING, residents are
       PREFILL/RUNNING, and the ``finished``/``preempted``/``rejected``
       maps hold exactly their terminal states with disjoint rids.
    4. **Terminal accounting** (with ``requests``) — every submitted
       request is in exactly one live or terminal bucket; a drained
       scheduler has them all terminal.
    """
    from .scheduler import RequestState  # local: avoid an import cycle

    fam = sched.family
    retained = (len(sched.prefix_index.entries)
                if sched.prefix_index is not None else 0)
    fam.check_integrity(retained=retained)

    if sched.prefix_index is not None and fam.supports_prefix_sharing:
        for page in sched.prefix_index.entries.values():
            _require(fam.unit_refcount(int(page)) >= 1,
                     f"retained page {page} has no owner")

    # Slot partition: residents + free slots == all batch slots, no overlap.
    batch = fam.batch
    res_slots = [r.slot for r in sched.resident]
    _require(len(set(res_slots)) == len(res_slots),
             f"duplicate resident slots: {res_slots}")
    _require(all(0 <= s < batch for s in res_slots),
             f"resident slot out of range: {res_slots}")
    _require(not (set(res_slots) & set(sched._free_slots)),
             "slot simultaneously resident and free")
    _require(sorted(res_slots + list(sched._free_slots)) == list(range(batch)),
             "resident + free slots do not partition the batch")

    # State discipline per bucket.
    for r in sched.queue:
        _require(r.state is RequestState.WAITING,
                 f"queued request {r.rid} in state {r.state}")
        _require(r.slot == -1, f"queued request {r.rid} holds slot {r.slot}")
    for r in sched.resident:
        _require(r.state in (RequestState.PREFILL, RequestState.RUNNING),
                 f"resident request {r.rid} in state {r.state}")
    for rid, r in sched.finished.items():
        _require(r.state is RequestState.FINISHED and r.done,
                 f"finished request {rid} in state {r.state}")
    for rid, r in sched.preempted.items():
        _require(r.state is RequestState.PREEMPTED,
                 f"preempted request {rid} in state {r.state}")
        _require(r.slot == -1, f"preempted request {rid} holds a slot")
    for rid, r in sched.rejected.items():
        _require(r.state is RequestState.REJECTED,
                 f"rejected request {rid} in state {r.state}")
        _require(r.reject_reason is not None,
                 f"rejected request {rid} carries no reason")
    terminal_rids = (set(sched.finished) | set(sched.preempted)
                     | set(sched.rejected))
    _require(
        len(terminal_rids) == (len(sched.finished) + len(sched.preempted)
                               + len(sched.rejected)),
        "a request is in more than one terminal bucket")

    # Every submitted request is in exactly one place.
    if requests is not None:
        live = {r.rid for r in sched.queue} | {r.rid for r in sched.resident}
        _require(not (live & terminal_rids),
                 "request simultaneously live and terminal")
        for r in requests:
            n_homes = (int(r.rid in live) + int(r.rid in sched.finished)
                       + int(r.rid in sched.preempted)
                       + int(r.rid in sched.rejected))
            _require(n_homes == 1,
                     f"request {r.rid} is in {n_homes} buckets (want 1)")


def terminal_states(requests) -> Dict[int, str]:
    """rid → terminal state name; raises if any request is still live."""
    out = {}
    for r in requests:
        _require(r.state.value in ("finished", "preempted", "rejected"),
                 f"request {r.rid} never reached a terminal state "
                 f"(stuck in {r.state.value})")
        out[r.rid] = r.state.value
    return out
