"""Draft-token providers for speculative decoding.

A :class:`Drafter` proposes the ``K-1`` cheap draft tokens that ride after
the feed token into each multi-query verify launch
(:func:`repro.kernels.ops.paged_verify`).  All three methods are traced
into the fused ``lax.scan`` verify loop, so they must be pure jnp over
device arrays — the drafter state lives in the scan carry and never
crosses to the host on the hot path.

Correctness does not depend on the drafter at all: greedy verify emits
``argmax`` tokens of the *target* model only, and the first-mismatch
acceptance rule discards every draft the target disagrees with.  A wrong
draft costs throughput (fewer tokens per page walk), never bits — which
is why eviction replay can ignore drafter state entirely and still
rebuild sequences bit-for-bit.

Two deterministic drafters ship here:

* :class:`NGramDrafter` — a per-slot bigram table updated on device from
  the accepted tokens.  Free (no extra matmuls), and effective exactly
  where greedy decode is most repetitive.
* :class:`TinyLMDrafter` — a tied-embedding greedy head
  (``argmax(embed[t] @ embed.T)`` chained ``K-1`` times).  The
  "small-model" hook: hand it any :class:`~repro.serve.paged_lm.PagedLM`'s
  embedding (e.g. a cheaper small-config model) and it drafts with that
  model's bigram preferences, KV-cache-free.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["Drafter", "NGramDrafter", "TinyLMDrafter"]


class Drafter:
    """Protocol for speculative draft-token providers.

    ``state`` is an arbitrary pytree of device arrays (possibly empty); it
    rides the verify scan carry, so every method must be jnp-traceable.
    One instance is baked into each jitted verify program, so a drafter
    must be immutable after construction.
    """

    def init_state(self, batch: int) -> Any:
        """Fresh drafter state for ``batch`` slots (pytree of arrays)."""
        raise NotImplementedError

    def draft(self, state: Any, feed: jax.Array, k: int) -> jax.Array:
        """Propose ``k`` draft tokens per slot following ``feed`` (B,).

        Returns (B, k) int32 — chained: draft ``i`` continues draft
        ``i-1``.  ``k == 0`` (spec_k == 1) must return a (B, 0) array.
        """
        raise NotImplementedError

    def update(self, state: Any, q_tokens: jax.Array, greedy: jax.Array,
               n_emit: jax.Array) -> Any:
        """Fold one verify step's outcome back into the state.

        q_tokens (B, K) are the scored tokens, ``greedy`` (B, K) the
        target model's argmax after each, ``n_emit`` (B,) how many were
        emitted — positions ``i < n_emit[b]`` are *known* transitions
        ``q_tokens[b, i] -> greedy[b, i]``; everything past that is
        speculation the target rejected and must not be learned.
        """
        raise NotImplementedError


def _empty_drafts(feed: jax.Array) -> jax.Array:
    return jnp.zeros((feed.shape[0], 0), jnp.int32)


class NGramDrafter(Drafter):
    """Per-slot device-resident bigram table (token -> predicted next).

    State is a (B, vocab) int32 table, zero-initialized (every unseen
    token predicts token 0).  Drafting chains ``k`` lookups from the feed
    token; the update scatters each emitted transition, with rejected
    positions routed out of bounds and dropped — all O(B·K) int ops, no
    extra model flops.
    """

    def __init__(self, vocab: int):
        self.vocab = vocab

    def init_state(self, batch: int) -> jax.Array:
        return jnp.zeros((batch, self.vocab), jnp.int32)

    def draft(self, state: jax.Array, feed: jax.Array, k: int) -> jax.Array:
        if k == 0:
            return _empty_drafts(feed)
        t = feed.astype(jnp.int32)
        out = []
        for _ in range(k):
            t = jnp.take_along_axis(state, t[:, None], axis=1)[:, 0]
            out.append(t)
        return jnp.stack(out, axis=1)

    def update(self, state: jax.Array, q_tokens: jax.Array,
               greedy: jax.Array, n_emit: jax.Array) -> jax.Array:
        b, k = q_tokens.shape
        rows = jnp.arange(b, dtype=jnp.int32)
        for i in range(k):
            # Rejected/clamped positions scatter to column ``vocab`` (OOB)
            # and are dropped — only emitted transitions are learned.
            col = jnp.where(i < n_emit, q_tokens[:, i], self.vocab)
            state = state.at[rows, col].set(greedy[:, i], mode="drop")
        return state


class TinyLMDrafter(Drafter):
    """Stateless tied-embedding greedy head over a draft embedding matrix.

    ``draft`` chains ``t -> argmax(embed[t] @ embed.T)`` — the zero-layer
    limit of a small-config :class:`~repro.serve.paged_lm.PagedLM` run
    greedily without a KV cache.  Pass any model's ``params["embed"]``
    (typically a smaller config than the target) to draft with its
    next-token preferences at one matvec per draft position.
    """

    def __init__(self, embed: jax.Array, vocab: int | None = None):
        self.embed = embed
        self.vocab = int(vocab if vocab is not None else embed.shape[0])

    def init_state(self, batch: int) -> tuple:
        return ()

    def draft(self, state: tuple, feed: jax.Array, k: int) -> jax.Array:
        if k == 0:
            return _empty_drafts(feed)
        t = feed.astype(jnp.int32)
        out = []
        for _ in range(k):
            logits = jnp.take(self.embed, t, axis=0) @ self.embed.T
            t = jnp.argmax(logits[:, : self.vocab], axis=-1).astype(jnp.int32)
            out.append(t)
        return jnp.stack(out, axis=1)

    def update(self, state: tuple, q_tokens: jax.Array, greedy: jax.Array,
               n_emit: jax.Array) -> tuple:
        return state
