"""Serving benchmark: continuous-batching decode throughput over paged
AXI-Pack streams.

For each batch size B, submits B variable-length requests to the
:class:`repro.serve.Scheduler` and measures end-to-end decode throughput
plus the per-step BASE-vs-PACK bus traffic (the serving-side instance of the
Fig. 3 accounting: BASE streams the padded contiguous cache, PACK streams
only mapped pages plus the near-memory page-table fetch).  A separate timed
phase measures batched *prefill* throughput in isolation (the scheduler's
``prefill_batch`` calls without decode interleaved), alongside the
prefill-side PACK/BASE efficiencies aggregated from the scheduler's
per-step records.

The same sweep re-runs with ``kv_dtype='int8'`` (the ``serving_int8``
section): quantize-on-write page pools, in-kernel dequant, and the 8-bit
packing factor in the PACK accounting — pool bytes quartered vs fp32 and
4x the elements per bus granule, the paper's element-size lever (§III-E)
applied to serving.

The ``serving_shared_prefix`` section measures prefix sharing: batches
whose prompts repeat one page-aligned system prompt run once with
``prefix_sharing=True`` and once without, asserting bit-for-bit identical
outputs, and report the fraction of prompt tokens whose prefill was
replaced by a refcount bump plus the effective prefill PACK efficiency
(shared tokens cost only the remapped table indices — the Ferry-style
dedup-before-packing multiplier on the serving path).

The ``serving_families`` section serves *recurrent* models (RWKV6, Mamba)
through the very same scheduler via the :class:`repro.serve.ServableFamily`
protocol: fixed-size state slots instead of growing page chains, and
strided-burst PACK/BASE accounting (no index-bus term — the stride is the
descriptor) instead of indirect page walks.  Each row asserts the scheduled
outputs are bit-for-bit the direct sequential forward
(:func:`repro.serve.recurrent_reference_generate` at the same batch shape)
before reporting throughput, so the benchmark doubles as the family
protocol's end-to-end correctness gate.

The measured run is steady-state: the warmup pass executes the *same*
workload so every jit entry the fused decode fast path uses (pow2 scan
lengths, prefill context buckets) is compiled before the clock starts, and
the reported wall time is the best of ``repeats`` timed runs (scheduler
wall-clock is tens of ms here, well inside host-noise territory).
Wall-clock numbers are CPU ``impl='ref'`` timings — regression signal for
this host, not TPU predictions (the roofline section covers the target).
The traffic columns are exact byte counts and *are* paper-comparable.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

import jax
import numpy as np

from repro.configs import smoke_config
from repro.serve import (
    FaultPlan,
    PagedKVCache,
    PagedLM,
    RecurrentLM,
    Request,
    Scheduler,
    build_prefill_rows,
    recurrent_reference_generate,
)

PAGE = 8
MAX_LEN = 64
CHUNK = 8


def _create_cache(model: PagedLM, batch: int) -> PagedKVCache:
    # Pools at the model's exact kv dtype: the Scheduler rejects mismatches.
    return PagedKVCache.create(
        model.cfg, batch=batch, max_len=MAX_LEN, page=PAGE,
        kv_dtype=model.kv_dtype,
    )


def _run_once(model: PagedLM, prompts, n_new: int) -> Scheduler:
    cache = _create_cache(model, len(prompts))
    sched = Scheduler(model, cache, chunk=CHUNK)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=n_new))
    sched.run()
    return sched


def _prefill_once(model: PagedLM, prompts) -> float:
    """One batched chunked prefill of every prompt (the scheduler's prefill
    phase in isolation: same ``prefill_batch`` calls, no decode).

    Batch assembly goes through the scheduler's own
    :func:`repro.serve.build_prefill_rows` (finished prompts drop out,
    pow2-bucketed rows), so the timed work is exactly what
    ``Scheduler._prefill_all`` issues.  Returns the wall seconds of the
    prefill loop only — cache creation and page allocation happen before
    the clock starts (the pools are donated, so the cache must be rebuilt
    per repeat, but that setup is host bookkeeping, not prefill).
    """
    b = len(prompts)
    cache = _create_cache(model, b)
    for i, p in enumerate(prompts):
        cache = cache.allocate(i, cache.pages_for(len(p)))
    pos = [0] * b
    pending = list(range(b))
    logits = None
    t0 = time.perf_counter()
    while pending:
        toks, counts, slots, starts = build_prefill_rows(
            [(prompts[j], pos[j], j) for j in pending], CHUNK, b
        )
        logits, cache = model.prefill_batch(toks, counts, slots, starts, cache)
        for i, j in enumerate(pending):
            pos[j] += int(counts[i])
        pending = [j for j in pending if pos[j] < len(prompts[j])]
    jax.block_until_ready(logits)
    return time.perf_counter() - t0


def _prefill_throughput(model: PagedLM, prompts, repeats: int) -> float:
    """Prompt tokens/s of the batched prefill phase (best of ``repeats``)."""
    tokens = sum(len(p) for p in prompts)
    _prefill_once(model, prompts)  # warmup: compile the ctx buckets
    wall = min(_prefill_once(model, prompts) for _ in range(max(1, repeats)))
    return tokens / wall


def shared_prefix_rows(
    batch_sizes: Sequence[int] = (2, 4, 8),
    n_new: int = 8,
    sys_tokens: int = 32,
    quick: bool = False,
    repeats: int = 3,
) -> List[Dict]:
    """Prefix-sharing sweep: every prompt in a batch repeats one
    page-aligned ``sys_tokens``-token system prompt with a distinct short
    tail.  Each batch runs through a sharing and a non-sharing scheduler
    (fresh caches, identical submissions) and the row asserts the outputs
    are bit-for-bit equal before reporting the savings — a benchmark that
    fails loudly if the replay contract breaks.
    """
    if quick:
        batch_sizes = (2, 4)
    assert sys_tokens % PAGE == 0, "system prompt must be page-aligned"
    cfg = smoke_config("yi-6b")
    model = PagedLM(cfg, jax.random.PRNGKey(0), impl="ref")
    rng = np.random.default_rng(7)
    rows = []
    for b in batch_sizes:
        sys_prompt = rng.integers(0, cfg.vocab, sys_tokens)
        prompts = [
            np.concatenate(
                [sys_prompt, rng.integers(0, cfg.vocab, int(t))]
            ).astype(np.int32)
            for t in rng.integers(4, 9, b)
        ]

        def _run(sharing: bool) -> Scheduler:
            cache = _create_cache(model, b)
            sched = Scheduler(model, cache, chunk=CHUNK,
                              prefix_sharing=sharing)
            for i, p in enumerate(prompts):
                sched.submit(Request(rid=i, prompt=p, max_new=n_new))
            sched.run()
            return sched

        for sharing in (True, False):
            _run(sharing)               # warmup: compile all jit entries
        wall = {True: float("inf"), False: float("inf")}
        for _ in range(max(1, repeats)):
            for sharing in (True, False):
                t0 = time.perf_counter()
                sched = _run(sharing)
                wall[sharing] = min(wall[sharing], time.perf_counter() - t0)
                if sharing:
                    shared_sched = sched
                else:
                    plain_sched = sched
        out_s = {r: shared_sched.finished[r].generated
                 for r in shared_sched.finished}
        out_p = {r: plain_sched.finished[r].generated
                 for r in plain_sched.finished}
        assert out_s == out_p, "prefix sharing changed outputs"
        st = shared_sched.stats
        prompt_tokens = sum(len(p) for p in prompts)
        rows.append({
            "batch": b,
            "prompt_tokens": prompt_tokens,
            "prefill_tokens_saved": st.prefill_tokens_saved,
            "saved_frac": st.prefill_tokens_saved / prompt_tokens,
            "shared_pages": st.shared_pages,
            "share_events": st.share_events,
            "cow_copies": st.cow_copies,
            "prefill_pack_eff": st.prefill_pack_efficiency,
            "effective_pack_eff": st.prefill_effective_pack_efficiency,
            "plain_pack_eff": plain_sched.stats.prefill_pack_efficiency,
            "wall_s": wall[True],
            "wall_s_plain": wall[False],
            "tokens_per_s": st.tokens / wall[True],
            "outputs_match": True,
        })
    return rows


def degradation_rows(
    n_reqs: int = 6,
    n_new: int = 8,
    quick: bool = False,
    fractions: Sequence[float] = (1.0, 0.5, 0.25, 0.12),
) -> List[Dict]:
    """Throughput under pool pressure: the robustness/degradation sweep.

    A fixed mixed-SLA workload (alternating priorities, deadlines on the
    interactive half, replay budgets on every third request) runs against
    pools shrunk to a fraction of the roomy full-pool footprint, plus one
    row with a seeded :class:`repro.serve.FaultPlan` injecting forced
    exhaustion / denied allocations on top of a halved pool.  Every row
    records the degradation counters (`evictions`, `preemptions`,
    `rejections`, `deadline_misses`) next to tokens/s, and asserts the
    liveness + correctness contract: **all requests terminal** (no
    deadlock, no crash) and **finished outputs bit-for-bit equal** to the
    full-pool fault-free reference.  CI fails the BENCH artifact if either
    flag is False.
    """
    if quick:
        fractions = (1.0, 0.5, 0.12)
    cfg = smoke_config("yi-6b")
    model = PagedLM(cfg, jax.random.PRNGKey(0), impl="ref")
    rng = np.random.default_rng(3)
    lens = rng.integers(4, 25, n_reqs)
    prompts = [rng.integers(0, cfg.vocab, int(n)).astype(np.int32)
               for n in lens]
    # Roomy footprint: every request fully grown at once.
    full = sum(-(-(len(p) + n_new - 1) // PAGE) for p in prompts)
    batch = min(n_reqs, 3)  # fewer slots than requests: real queueing

    def make_requests():
        return [
            Request(
                rid=i, prompt=p.copy(), max_new=n_new,
                priority=i % 2,
                deadline_steps=40 if i % 2 else None,
                replay_budget=(2 * (len(p) + n_new) if i % 3 == 0 else None),
            )
            for i, p in enumerate(prompts)
        ]

    def run(pool: int, faults) -> Scheduler:
        cache = PagedKVCache.create(
            cfg, batch=batch, max_len=MAX_LEN, page=PAGE, pool_pages=pool,
        )
        sched = Scheduler(model, cache, chunk=CHUNK, faults=faults)
        reqs = make_requests()
        for r in reqs:
            sched.submit(r, strict=False)
        sched.run(max_steps=2000)
        return sched

    run(full, None)  # warmup: compile every jit entry on the same workload
    reference = {
        rid: r.generated for rid, r in run(full, None).finished.items()
    }

    cases = [(f"pool×{f:g}", f, None) for f in fractions]
    cases.append(
        ("chaos pool×0.5", 0.5,
         FaultPlan.random(0, n_steps=24, p_exhaust=0.3, p_deny=0.2))
    )
    rows = []
    for label, frac, faults in cases:
        pool = max(2, int(round(full * frac)))
        t0 = time.perf_counter()
        sched = run(pool, faults)
        wall = time.perf_counter() - t0
        st = sched.stats
        terminal = (len(sched.finished) + len(sched.preempted)
                    + len(sched.rejected))
        rows.append({
            "label": label,
            "pool_frac": frac,
            "pool_pages": pool,
            "batch": batch,
            "chaos": faults is not None,
            "tokens": st.tokens,
            "wall_s": wall,
            "tokens_per_s": st.tokens / wall,
            "completed": len(sched.finished),
            "evictions": st.n_evictions,
            "preemptions": st.n_preempted,
            "rejections": st.n_rejected,
            "reject_reasons": dict(st.reject_reasons),
            "deadline_misses": st.deadline_misses,
            "all_terminal": terminal == n_reqs,
            "outputs_match": all(
                r.generated == reference[rid]
                for rid, r in sched.finished.items()
            ),
        })
    return rows


def family_rows(
    archs: Sequence[str] = ("rwkv6", "mamba"),
    batch_sizes: Sequence[int] = (2, 4),
    n_new: int = 8,
    max_prompt: int = 16,
    quick: bool = False,
    repeats: int = 3,
) -> List[Dict]:
    """Recurrent families through the shared scheduler, one row per
    (arch, batch).

    Every row first runs the workload once untimed to (a) compile all jit
    entries and (b) assert the scheduled outputs equal the direct
    sequential forward bit-for-bit (``outputs_match`` — CI fails the
    artifact when False).  The strided PACK efficiency is ≈ 1 by
    construction (dense fixed-stride state rows, no index tax) while BASE
    efficiency is the occupancy — the serving-side contrast between the
    paper's two packed burst dialects.
    """
    if quick:
        batch_sizes = (2,)
    arch_cfg = {"rwkv6": "rwkv6-3b", "mamba": "yi-6b"}
    rng = np.random.default_rng(5)
    rows = []
    for arch in archs:
        cfg = smoke_config(arch_cfg[arch])
        model = RecurrentLM(cfg, jax.random.PRNGKey(0), arch=arch,
                            impl="ref")
        for b in batch_sizes:
            lens = rng.integers(4, max_prompt + 1, b)
            prompts = [rng.integers(0, cfg.vocab, int(n)).astype(np.int32)
                       for n in lens]
            want = recurrent_reference_generate(
                model, model.init_pool(b), prompts, n_new
            )

            def _run() -> Scheduler:
                sched = Scheduler(model, model.init_pool(b), chunk=CHUNK)
                for i, p in enumerate(prompts):
                    sched.submit(Request(rid=i, prompt=p, max_new=n_new))
                sched.run()
                return sched

            warm = _run()  # warmup + correctness gate
            out = {rid: r.generated for rid, r in warm.finished.items()}
            match = out == {i: want[i] for i in range(b)}
            wall = float("inf")
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                sched = _run()
                wall = min(wall, time.perf_counter() - t0)
            st = sched.stats
            fam = sched.family
            rows.append({
                "family": arch,
                "batch": b,
                "tokens": st.tokens,
                "wall_s": wall,
                "tokens_per_s": st.tokens / wall,
                "decode_steps": st.decode_steps,
                "pack_kib": st.pack_bytes / 2**10,
                "base_kib": st.base_bytes / 2**10,
                "pack_eff": st.pack_efficiency,
                "base_eff": st.base_efficiency,
                "prefill_pack_eff": st.prefill_pack_efficiency,
                "prefill_base_eff": st.prefill_base_efficiency,
                "prompt_tokens": sum(len(p) for p in prompts),
                "state_slot_bytes": fam.state_bytes(1),
                "pool_bytes": fam.pool_bytes,
                "outputs_match": match,
            })
    return rows


def spec_rows(
    batch_sizes: Sequence[int] = (1, 2, 4, 8),
    n_new: int = 48,
    max_prompt: int = 16,
    quick: bool = False,
    repeats: int = 5,
    spec_ks: Sequence[int] = (2, 4),
    kv_dtype: str = None,
) -> List[Dict]:
    """Speculative-decoding sweep: one row per (batch, spec_k).

    Same weights and scheduler as :func:`serving_rows`; the ``spec_k=1``
    baseline timed per batch is the plain fused-decode path, re-run on
    this sweep's decode-heavy workload (short prompts, long generations —
    speculation amortizes *decode-time* page walks, so the decode phase is
    what the ratio must isolate; prompt processing is the prefill rows'
    story).  Each speculative row re-runs the identical workload through a
    ``spec_k``-wide model — the n-gram drafter proposes ``spec_k - 1``
    tokens per step and one ``paged_verify`` launch scores all of them in
    a single clamped page walk — and **asserts the emitted outputs are
    bit-for-bit the plain greedy outputs** (``outputs_match``; CI fails
    the artifact when False).  Reported next to tokens/s:
    ``acceptance_rate`` (drafts the verifier kept), ``speedup_vs_plain``
    (decode tokens/s over the spec_k=1 run), and the verify-dialect
    PACK/BASE efficiencies (BASE is the K-narrow-walks counterfactual the
    multi-query walk replaces).
    """
    if quick:
        batch_sizes = (1, 4)
        n_new = 24
    cfg = smoke_config("yi-6b")
    models = {
        k: PagedLM(cfg, jax.random.PRNGKey(0), impl="ref", spec_k=k,
                   kv_dtype=kv_dtype)
        for k in (1,) + tuple(spec_ks)
    }

    def _spec_cache(model: PagedLM, batch: int) -> PagedKVCache:
        # Longer slots than the main sweep: generations here run past
        # MAX_LEN so the decode phase dominates the measured wall.
        return PagedKVCache.create(
            model.cfg, batch=batch, max_len=2 * MAX_LEN, page=PAGE,
            kv_dtype=model.kv_dtype,
        )

    rng = np.random.default_rng(0)
    rows = []
    for b in batch_sizes:
        lens = rng.integers(4, max_prompt + 1, b)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in lens]

        def _time(model):
            def once():
                cache = _spec_cache(model, len(prompts))
                sched = Scheduler(model, cache, chunk=CHUNK)
                for i, p in enumerate(prompts):
                    sched.submit(Request(rid=i, prompt=p, max_new=n_new))
                sched.run()
                return sched

            once()  # warmup: same workload, all jit entries
            wall, sched = float("inf"), None
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                s = once()
                dt = time.perf_counter() - t0
                if dt < wall:
                    wall, sched = dt, s
            return sched, wall

        plain_sched, plain_wall = _time(models[1])
        plain_out = {rid: r.generated
                     for rid, r in plain_sched.finished.items()}
        plain_tps = plain_sched.stats.tokens / plain_wall
        for k in spec_ks:
            sched, wall = _time(models[k])
            out = {rid: r.generated for rid, r in sched.finished.items()}
            st = sched.stats
            rows.append({
                "batch": b,
                "spec_k": k,
                "tokens": st.tokens,
                "wall_s": wall,
                "tokens_per_s": st.tokens / wall,
                "plain_tokens_per_s": plain_tps,
                "speedup_vs_plain": (st.tokens / wall) / plain_tps,
                "acceptance_rate": st.acceptance_rate,
                "drafted": st.n_drafted,
                "accepted": st.n_accepted,
                "emitted": st.n_emitted,
                "verify_steps": st.spec_steps,
                "plain_decode_steps": plain_sched.stats.decode_steps,
                "pack_eff": st.pack_efficiency,
                "base_eff": st.base_efficiency,
                "kv_elem_bits": models[k].kv_elem_bits,
                "outputs_match": out == plain_out,
            })
    return rows


def serving_rows(
    batch_sizes: Sequence[int] = (1, 2, 4, 8),
    n_new: int = 16,
    max_prompt: int = 24,
    quick: bool = False,
    repeats: int = 5,
    kv_dtype: str = None,
) -> List[Dict]:
    """One row per batch size; ``kv_dtype='int8'`` serves from quantized
    pools (quantize-on-write + in-kernel dequant) — same prompts, same
    workload, so rows are directly comparable to the full-precision sweep.
    """
    if quick:
        batch_sizes = (1, 4)
        n_new = 8
    cfg = smoke_config("yi-6b")
    model = PagedLM(cfg, jax.random.PRNGKey(0), impl="ref", kv_dtype=kv_dtype)
    rng = np.random.default_rng(0)
    rows = []
    for b in batch_sizes:
        lens = rng.integers(4, max_prompt + 1, b)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in lens]
        _run_once(model, prompts, n_new)  # warmup: same workload, all jits
        wall = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            sched = _run_once(model, prompts, n_new)
            wall = min(wall, time.perf_counter() - t0)
        st = sched.stats
        rows.append({
            "batch": b,
            "tokens": st.tokens,
            "wall_s": wall,
            "tokens_per_s": st.tokens / wall,
            "decode_steps": st.decode_steps,
            "steps_per_s": st.decode_steps / wall,
            "evictions": st.n_evictions,
            "pack_kib": st.pack_bytes / 2**10,
            "base_kib": st.base_bytes / 2**10,
            "pack_eff": st.pack_efficiency,
            "base_eff": st.base_efficiency,
            "prompt_tokens": sum(len(p) for p in prompts),
            "prefill_steps": st.prefill_steps,
            "prefill_tokens_per_s": _prefill_throughput(
                model, prompts, repeats
            ),
            "prefill_pack_eff": st.prefill_pack_efficiency,
            "prefill_base_eff": st.prefill_base_efficiency,
            "kv_elem_bits": model.kv_elem_bits,
            "pool_bytes": sched.cache.pool_bytes,
        })
    return rows
