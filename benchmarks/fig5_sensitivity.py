"""Fig. 5 reproductions: element/index size and bank-count sensitivity.

Protocol per §III-E: ideal requestor issuing length-256 reads, random
indices, decoupling queues deepened to 32.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.banksim import (
    BankConfig,
    crossbar_area_kge,
    indirect_utilization,
    strided_utilization,
)

BANK_COUNTS = (8, 11, 16, 17, 23, 32, 31)


def fig5a_indirect(
    pairs=((32, 32), (32, 16), (32, 8), (64, 32), (64, 16)),
    bank_counts=BANK_COUNTS,
    burst_len: int = 256,
) -> List[Dict]:
    rows = []
    for elem_bits, index_bits in pairs:
        for banks in bank_counts:
            cfg = BankConfig(n_ports=8, n_banks=banks, queue_depth=32)
            u = indirect_utilization(cfg, elem_bits, index_bits, burst_len)
            r = elem_bits / index_bits
            rows.append({
                "elem_bits": elem_bits, "index_bits": index_bits,
                "banks": banks, "utilization": u,
                "ceiling_r_over_r1": r / (r + 1),
            })
    return rows


def fig5b_strided(
    elem_bits_list=(32, 64), bank_counts=BANK_COUNTS,
    strides=range(0, 64), burst_len: int = 256,
) -> List[Dict]:
    rows = []
    for elem_bits in elem_bits_list:
        for banks in bank_counts:
            cfg = BankConfig(n_ports=8, n_banks=banks, queue_depth=32)
            us = [strided_utilization(max(s, 1), cfg, elem_bits, burst_len)
                  for s in strides]
            rows.append({
                "elem_bits": elem_bits, "banks": banks,
                "mean_utilization": float(np.mean(us)),
                "prime": banks in (11, 17, 23, 31),
            })
    return rows


def fig5c_crossbar_area(bank_counts=BANK_COUNTS) -> List[Dict]:
    return [
        {"banks": b, "area_kge": crossbar_area_kge(8, b),
         "prime": b in (11, 17, 23, 31)}
        for b in sorted(bank_counts)
    ]
