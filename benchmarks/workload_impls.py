"""Executable JAX implementations of the paper's six workloads.

These run end-to-end on this host (correctness-checked against numpy) using
the packed-stream ops, and report *exact* packed-vs-base traffic from the
accounting model — the measured counterpart of the cycle model in
``paper_workloads`` (the cycle model supplies time; this supplies bytes and
verified semantics).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import indirect_traffic, strided_traffic
from repro.kernels import ops, ref


def ismt(x: jax.Array, impl: str = "pallas") -> Tuple[jax.Array, Dict]:
    """In-situ transpose via packed tile streams."""
    n = x.shape[0]
    out = ops.tiled_transpose(x, block=min(128, n), impl=impl)
    t = strided_traffic(count=n * n, elem_bytes=4, stride=n)
    return out, {"base_eff": t.base_efficiency, "pack_eff": t.pack_efficiency}


def gemv_col(a: jax.Array, x: jax.Array) -> Tuple[jax.Array, Dict]:
    """Column dataflow: strided column streams, no reductions."""
    n = a.shape[0]
    y = jnp.einsum("rc,c->r", a, x)  # columns stream through the MXU
    t = strided_traffic(count=n * n, elem_bytes=4, stride=n)
    return y, {"base_eff": t.base_efficiency, "pack_eff": t.pack_efficiency}


def trmv(a: jax.Array, x: jax.Array) -> Tuple[jax.Array, Dict]:
    n = a.shape[0]
    au = jnp.triu(a)
    y = jnp.einsum("rc,c->r", au, x)
    nnz = n * (n + 1) // 2
    t = strided_traffic(count=nnz, elem_bytes=4, stride=n)
    return y, {"base_eff": t.base_efficiency, "pack_eff": t.pack_efficiency}


def spmv(vals, cols, x, impl: str = "pallas") -> Tuple[jax.Array, Dict]:
    y = ops.spmv_ell(vals, cols, x, impl=impl)
    nnz = int(vals.shape[0] * vals.shape[1])
    t = indirect_traffic(count=nnz, elem_bytes=4, index_bytes=4)
    return y, {"base_eff": t.base_efficiency, "pack_eff": t.pack_efficiency}


def pagerank(
    vals, cols, n: int, iters: int = 20, damping: float = 0.85,
    impl: str = "ref",
) -> Tuple[jax.Array, Dict]:
    """Power iteration on the (row-normalized) adjacency in ELL form."""
    r = jnp.full((n,), 1.0 / n, jnp.float32)

    def body(r, _):
        new = damping * ops.spmv_ell(vals, cols, r, impl=impl) + (1 - damping) / n
        return new, None

    r, _ = jax.lax.scan(body, r, None, length=iters)
    nnz = int(vals.shape[0] * vals.shape[1]) * iters
    t = indirect_traffic(count=nnz, elem_bytes=4, index_bytes=4)
    return r, {"base_eff": t.base_efficiency, "pack_eff": t.pack_efficiency}


def sssp(
    wvals, cols, mask, src: int, n: int, iters: int,
) -> Tuple[jax.Array, Dict]:
    """Bellman-Ford on an ELL adjacency (min-plus spmv per sweep).

    dist[v] = min(dist[v], min_u dist[u] + w[u][v]) — implemented row-wise:
    candidate[r] = min_k (dist[cols[r,k]] + wvals[r,k]).
    """
    inf = jnp.float32(1e30)
    dist = jnp.full((n,), inf).at[src].set(0.0)

    def sweep(dist, _):
        gathered = jnp.take(dist, cols, axis=0)          # indirect stream
        cand = jnp.where(mask, gathered + wvals, inf).min(axis=1)
        return jnp.minimum(dist, cand), None

    dist, _ = jax.lax.scan(sweep, dist, None, length=iters)
    nnz = int(wvals.shape[0] * wvals.shape[1]) * iters
    t = indirect_traffic(count=nnz, elem_bytes=4, index_bytes=4)
    return dist, {"base_eff": t.base_efficiency, "pack_eff": t.pack_efficiency}
