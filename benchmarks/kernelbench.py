"""Kernel micro-benchmarks: wall-time of jitted ops on this host (CPU) plus
exact packed-vs-base traffic accounting (the HBM energy proxy).

Wall-times on CPU are NOT TPU predictions — the roofline analysis covers the
target; these catch regressions and show the ref-path speed of each op.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import indirect_traffic, strided_traffic
from repro.kernels import ops, ref


def _time(fn: Callable, *args, reps: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # Stream converters (ref impl = the XLA path used in training).
    src = jnp.asarray(rng.normal(size=(4096, 256)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, 1024), jnp.int32)
    f_ref = jax.jit(lambda s, i: ref.indirect_gather(s, i))
    rows.append({"name": "indirect_gather_ref_4096x256",
                 "us_per_call": _time(f_ref, src, idx),
                 "derived": "1024 rows"})

    g_ref = jax.jit(lambda s: ref.strided_gather(s, 0, 4, 1024))
    rows.append({"name": "strided_gather_ref_4096x256",
                 "us_per_call": _time(g_ref, src), "derived": "stride 4"})

    t = strided_traffic(count=1024 * 256, elem_bytes=4, stride=4)
    rows.append({"name": "strided_traffic_efficiency",
                 "us_per_call": 0.0,
                 "derived": f"base {t.base_efficiency:.3f} pack {t.pack_efficiency:.3f}"})
    ti = indirect_traffic(count=1024 * 256, elem_bytes=4, index_bytes=4)
    rows.append({"name": "indirect_traffic_efficiency",
                 "us_per_call": 0.0,
                 "derived": f"base {ti.base_efficiency:.3f} pack {ti.pack_efficiency:.3f}"})

    # spmv
    vals = jnp.asarray(rng.normal(size=(512, 64)), jnp.float32)
    cols = jnp.asarray(rng.integers(0, 2048, (512, 64)), jnp.int32)
    x = jnp.asarray(rng.normal(size=(2048,)), jnp.float32)
    f = jax.jit(lambda v, c, xx: ref.spmv_ell(v, c, xx))
    rows.append({"name": "spmv_ell_ref_512x64",
                 "us_per_call": _time(f, vals, cols, x),
                 "derived": f"{512*64} nnz"})

    # attention (ref chunked path = the training path)
    from repro.models.common import chunked_mha
    q = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.bfloat16)
    f = jax.jit(lambda q_, k_, v_: chunked_mha(q_, k_, v_, kv_chunk=128))
    rows.append({"name": "chunked_mha_512_gqa4",
                 "us_per_call": _time(f, q, k, k), "derived": "bf16"})

    # MoE dispatch/combine (XLA path)
    tok = jnp.asarray(rng.normal(size=(2048, 256)), jnp.float32)
    eidx = jnp.asarray(rng.integers(0, 16, (2048, 2)), jnp.int32)
    f = jax.jit(lambda t_, e_: ref.moe_dispatch(t_, e_, 16, 320))
    rows.append({"name": "moe_dispatch_2048tok_16e",
                 "us_per_call": _time(f, tok, eidx), "derived": "top2 cap320"})

    # decayed cumsum (SSM/RWKV core)
    from repro.models.common import decayed_cumsum
    a = jnp.asarray(rng.random((512, 64, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 64, 16)), jnp.float32)
    h0 = jnp.zeros((64, 16), jnp.float32)
    f = jax.jit(lambda a_, b_, h_: decayed_cumsum(a_, b_, h_, chunk=64))
    rows.append({"name": "decayed_cumsum_T512",
                 "us_per_call": _time(f, a, b, h0), "derived": "chunk 64"})
    return rows
