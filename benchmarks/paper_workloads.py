"""The paper's six benchmarks as (a) cycle-model workloads and (b) real JAX
implementations with exact traffic accounting.

Calibration: exactly two constants shared across ALL workloads —
``iter_overhead = 5`` cycles (loop/issue) and ``reduction_latency = 48``
cycles (Ara's cross-lane reduction tree; calibrated once on gemv-row's 37 %
utilization, then reused unchanged).  Everything else is first-principles
from the stream descriptors; the test suite asserts the model lands within
tolerance of the paper's measured numbers (Fig. 3a).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    BusConfig,
    ContiguousStream,
    IndirectStream,
    StridedStream,
    System,
    WorkloadModel,
)
from repro.core.busmodel import Iteration
from repro.core.banksim import BankConfig, simulate_stream

CFG = BusConfig()
BANKS = BankConfig(n_ports=8, n_banks=17, queue_depth=4)

E32 = 32  # fp32 elements / int32 indices


def _conflict_fn(stream):
    """PACK-side bank-conflict stalls from the endpoint simulator.

    The analytic cycle model already charges indirect streams their
    index-line port-sharing term, so only conflict cycles *beyond* the
    analytic cost are added here (no double counting).
    """
    from repro.core.streams import BurstKind
    from repro.core import beats_for

    try:
        r = simulate_stream(stream, BANKS)
    except Exception:
        return 0.0
    analytic = r.data_beats
    if stream.kind is BurstKind.INDIRECT:
        analytic += beats_for(stream.count, CFG.bus_bits, stream.index_bits)
    return float(max(0, r.cycles - analytic))


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Strided workloads
# ---------------------------------------------------------------------------


def ismt_model(n: int = 256) -> WorkloadModel:
    """In-situ transpose: swap row-part and column-part of each row.

    Column access = stride-n stream.  Read-write ordering serializes the
    iteration (the paper's 50 % read-bus ceiling on ismt).
    """
    its = []
    for i in range(n - 1):
        m = n - 1 - i
        its.append(Iteration(
            streams=[
                ContiguousStream(base=0, elem_bits=E32, count=m),
                StridedStream(base=0, elem_bits=E32, count=m, stride=n),
                ContiguousStream(base=0, elem_bits=E32, count=m),
                StridedStream(base=0, elem_bits=E32, count=m, stride=n),
            ],
            compute_ops=2 * m,
            serialize=True,
        ))
    return WorkloadModel("ismt", its, CFG, _conflict_fn)


def gemv_model(n: int = 256, dataflow: str = "col") -> WorkloadModel:
    """gemv: row-wise = contiguous + reduction; col-wise = strided, no reduction."""
    its = []
    if dataflow == "col":
        for _ in range(n):
            its.append(Iteration(
                streams=[StridedStream(base=0, elem_bits=E32, count=n, stride=n)],
                compute_ops=n,
            ))
    else:
        for _ in range(n):
            its.append(Iteration(
                streams=[ContiguousStream(base=0, elem_bits=E32, count=n)],
                compute_ops=n,
                reductions=1,
                reduction_width=n,
            ))
    return WorkloadModel(f"gemv-{dataflow}", its, CFG, _conflict_fn)


def trmv_model(n: int = 256, dataflow: str = "col") -> WorkloadModel:
    """Upper-triangular gemv: stream lengths shrink along the matrix."""
    its = []
    for j in range(1, n + 1):
        if dataflow == "col":
            its.append(Iteration(
                streams=[StridedStream(base=0, elem_bits=E32, count=j, stride=n)],
                compute_ops=j,
            ))
        else:
            its.append(Iteration(
                streams=[ContiguousStream(base=0, elem_bits=E32, count=j)],
                compute_ops=j, reductions=1, reduction_width=j,
            ))
    return WorkloadModel(f"trmv-{dataflow}", its, CFG, _conflict_fn)


# ---------------------------------------------------------------------------
# Indirect workloads (CSR)
# ---------------------------------------------------------------------------


def synth_csr(n_rows: int, avg_nnz: int, n_cols: Optional[int] = None,
              seed: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Synthetic CSR with heart1-like statistics (SuiteSparse is offline-
    unavailable; heart1: n=3557, ~390 nnz/row — noted in EXPERIMENTS.md)."""
    rng = _rng(seed)
    n_cols = n_cols or n_rows
    counts = np.maximum(1, rng.poisson(avg_nnz, n_rows))
    counts = np.minimum(counts, n_cols)
    indptr = np.zeros(n_rows + 1, np.int64)
    indptr[1:] = np.cumsum(counts)
    indices = np.concatenate([
        np.sort(rng.choice(n_cols, c, replace=False)) for c in counts
    ]).astype(np.int32)
    data = rng.normal(size=indptr[-1]).astype(np.float32)
    return indptr, indices, data


def spmv_model(indptr, indices, name: str = "spmv") -> WorkloadModel:
    """CSR SpMV: per row, stream vals (contig) + x[cols] (indirect) + reduce."""
    its = []
    for r in range(len(indptr) - 1):
        lo, hi = int(indptr[r]), int(indptr[r + 1])
        nnz = hi - lo
        if nnz == 0:
            continue
        its.append(Iteration(
            streams=[
                ContiguousStream(base=0, elem_bits=E32, count=nnz),
                IndirectStream(base=0, elem_bits=E32, count=nnz,
                               indices=indices[lo:hi], index_bits=E32),
            ],
            compute_ops=2 * nnz,
            reductions=1,
            reduction_width=nnz,
        ))
    return WorkloadModel(name, its, CFG, _conflict_fn)


def prank_model(indptr, indices) -> WorkloadModel:
    """One PageRank power iteration = SpMV + rank update (axpy per row)."""
    m = spmv_model(indptr, indices, "prank")
    n = len(indptr) - 1
    m.iterations.append(Iteration(
        streams=[ContiguousStream(base=0, elem_bits=E32, count=n),
                 ContiguousStream(base=0, elem_bits=E32, count=n)],
        compute_ops=2 * n,
    ))
    return m


def sssp_model(indptr, indices) -> WorkloadModel:
    """One Bellman-Ford sweep: per row stream weights + dist[cols] (indirect),
    min-reduce, write-back — indirect-read-heavy like spmv but with a
    cheaper combine."""
    its = []
    for r in range(len(indptr) - 1):
        lo, hi = int(indptr[r]), int(indptr[r + 1])
        nnz = hi - lo
        if nnz == 0:
            continue
        its.append(Iteration(
            streams=[
                ContiguousStream(base=0, elem_bits=E32, count=nnz),
                IndirectStream(base=0, elem_bits=E32, count=nnz,
                               indices=indices[lo:hi], index_bits=E32),
            ],
            compute_ops=nnz,
            reductions=1,
            reduction_width=nnz,
        ))
    return WorkloadModel("sssp", its, CFG, _conflict_fn)


# ---------------------------------------------------------------------------
# Evaluation helpers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Fig3Row:
    name: str
    speedup_pack: float       # PACK vs BASE
    speedup_ideal: float      # IDEAL vs BASE
    util_pack: float          # read-bus utilization, data beats only
    util_pack_w_index: float
    pack_vs_ideal: float      # fraction of IDEAL performance PACK reaches


def evaluate(model: WorkloadModel) -> Fig3Row:
    r = model.evaluate_all()
    base, pack, ideal = r[System.BASE], r[System.PACK], r[System.IDEAL]
    return Fig3Row(
        name=model.name,
        speedup_pack=base.cycles / pack.cycles,
        speedup_ideal=base.cycles / ideal.cycles,
        util_pack=pack.bus_util,
        util_pack_w_index=pack.bus_util_with_index,
        pack_vs_ideal=ideal.cycles / pack.cycles,
    )


def fig3a_rows(n: int = 256, sparse_rows: int = 256, avg_nnz: int = 390,
               seed: int = 0) -> List[Fig3Row]:
    # heart1-like geometry: 3557 columns regardless of the row subsample
    indptr, indices, _ = synth_csr(sparse_rows, avg_nnz, n_cols=3557, seed=seed)
    rows = [
        evaluate(ismt_model(n)),
        evaluate(gemv_model(n, "col")),
        evaluate(trmv_model(n, "col")),
        evaluate(spmv_model(indptr, indices)),
        evaluate(prank_model(indptr, indices)),
        evaluate(sssp_model(indptr, indices)),
    ]
    return rows
