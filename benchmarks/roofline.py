"""§Roofline: three-term analysis per (arch × shape × mesh) from dry-run
artifacts.

    compute term    = HLO_FLOPs / (chips × 197 TF/s)        [per-device FLOPs]
    memory term     = HLO_bytes / (chips × 819 GB/s)
    collective term = wire_bytes / (chips × 50 GB/s/link)

Sources: per-device loop-aware dot FLOPs and collective wire bytes parsed
from the compiled HLO (launch.hlo_analysis); the memory term uses an
analytic traffic model (params + grads + optimizer state + remat-recomputed
activations; cost_analysis() 'bytes accessed' is reported alongside but
undercounts scan bodies).  MODEL_FLOPS = 6·N_active·D for train, 2·N_active·D
for a forward (prefill), 2·N_active per token for decode.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.configs import ALL_ARCH_NAMES, get_config
from repro.configs.base import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens / chips
    return 2.0 * n * shape.global_batch / chips  # decode: one token/seq


def analytic_hbm_bytes(arch: str, shape_name: str, chips: int, rec: Dict) -> float:
    """Per-device HBM traffic estimate for one step.

    train: params read (fwd+bwd+remat ≈ 3×) + grads written+read + optimizer
    state r/w + residual stack w/r.  serve: params read once + cache r/w.
    Uses the dry-run's own per-device argument bytes as the params+state
    footprint (exact, sharding-aware).
    """
    shape = SHAPES[shape_name]
    arg_bytes = rec["memory"]["argument_bytes"]
    if shape.kind == "train":
        # params+opt read + written once (aliased), grads transient ×2,
        # plus one full remat re-read of params per microbatch backward.
        return 3.0 * arg_bytes + 2.0 * rec["memory"]["temp_bytes"]
    # serving: weights + cache read, cache written incrementally
    return arg_bytes + rec["memory"]["temp_bytes"]


def load_cells(mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def roofline_rows(mesh: str = "pod16x16") -> List[Dict]:
    out = []
    for rec in load_cells(mesh):
        arch, shape = rec["arch"], rec["shape"]
        chips = rec["chips"]
        hlo_flops = rec.get("loop_aware_dot_flops_per_device", 0.0)
        wire = rec.get("collective_wire_bytes_per_device", 0.0)
        hbm = analytic_hbm_bytes(arch, shape, chips, rec)
        t_c = hlo_flops / PEAK_FLOPS
        t_m = hbm / HBM_BW
        t_n = wire / ICI_BW
        mf = model_flops_per_device(arch, shape, chips)
        dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
                       key=lambda kv: kv[1])[0]
        out.append({
            "arch": arch, "shape": shape, "mesh": mesh,
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dominant,
            "model_flops_per_dev": mf,
            "hlo_flops_per_dev": hlo_flops,
            "useful_flop_ratio": mf / hlo_flops if hlo_flops else float("nan"),
            "mem_gb_per_dev": rec["memory"]["peak_per_device_gb"],
            "roofline_fraction": (
                mf / PEAK_FLOPS / max(t_c, t_m, t_n)
                if max(t_c, t_m, t_n) > 0 else float("nan")
            ),
        })
    return out


def print_table(rows: List[Dict]) -> None:
    hdr = (f"{'arch':16s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dominant':>10s} {'useful':>7s} {'roofline':>9s} {'GB/dev':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:16s} {r['shape']:12s} {r['compute_s']:9.4f} "
              f"{r['memory_s']:9.4f} {r['collective_s']:9.4f} "
              f"{r['dominant']:>10s} {r['useful_flop_ratio']:7.2f} "
              f"{r['roofline_fraction']:9.3f} {r['mem_gb_per_dev']:7.2f}")
