"""Fig. 3b-e reproductions: dataflow comparison and input-size/bus-width scaling."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import BusConfig, System, WorkloadModel
from repro.core.banksim import BankConfig, simulate_stream

from .paper_workloads import (
    gemv_model, trmv_model, ismt_model, spmv_model, synth_csr, evaluate,
)


def _cfg_for_width(bus_bits: int) -> BusConfig:
    return BusConfig(bus_bits=bus_bits, lanes=bus_bits // 32)


def _banks_for_width(bus_bits: int) -> BankConfig:
    return BankConfig(n_ports=bus_bits // 32, n_banks=17)


def _with_width(model_fn, bus_bits: int, *args, **kwargs) -> WorkloadModel:
    m = model_fn(*args, **kwargs)
    m.cfg = _cfg_for_width(bus_bits)
    banks = _banks_for_width(bus_bits)

    def cf(stream):
        from repro.core.streams import BurstKind
        from repro.core import beats_for
        try:
            r = simulate_stream(stream, banks)
        except Exception:
            return 0.0
        analytic = r.data_beats
        if stream.kind is BurstKind.INDIRECT:
            analytic += beats_for(stream.count, m.cfg.bus_bits, stream.index_bits)
        return float(max(0, r.cycles - analytic))

    m.conflict_fn = cf
    return m


def fig3b_gemv_dataflows(n: int = 256) -> Dict[str, Dict[str, float]]:
    """Row vs column dataflow on each system (Fig. 3b)."""
    out = {}
    for flow in ("row", "col"):
        m = gemv_model(n, flow)
        r = m.evaluate_all()
        out[flow] = {
            s: r[s].cycles for s in (System.BASE, System.PACK, System.IDEAL)
        }
        out[flow]["util_pack"] = r[System.PACK].bus_util
        out[flow]["util_base"] = r[System.BASE].bus_util
    return out


def fig3c_trmv_dataflows(n: int = 256) -> Dict[str, Dict[str, float]]:
    out = {}
    for flow in ("row", "col"):
        m = trmv_model(n, flow)
        r = m.evaluate_all()
        out[flow] = {
            s: r[s].cycles for s in (System.BASE, System.PACK, System.IDEAL)
        }
        out[flow]["util_pack"] = r[System.PACK].bus_util
    return out


def fig3d_ismt_scaling(
    sizes=(8, 16, 32, 64, 128, 256), widths=(64, 128, 256)
) -> List[Dict]:
    """ismt speedup vs matrix size × bus width (Fig. 3d).

    Expectations from the paper: speedups converge with size (up to
    1.9/3.2/5.4× for 64/128/256-bit buses) and shrink for small matrices;
    PACK never loses to BASE (request bundling)."""
    rows = []
    for w in widths:
        for n in sizes:
            m = _with_width(ismt_model, w, n)
            base = m.evaluate(System.BASE).cycles
            pack = m.evaluate(System.PACK).cycles
            rows.append({"bus_bits": w, "n": n, "speedup": base / pack})
    return rows


def fig3e_spmv_scaling(
    nnz_list=(2, 8, 32, 128, 390), widths=(64, 128, 256), n_rows: int = 96
) -> List[Dict]:
    """spmv speedup vs avg nonzeros/row × bus width (Fig. 3e)."""
    rows = []
    for w in widths:
        for nnz in nnz_list:
            indptr, indices, _ = synth_csr(n_rows, nnz, n_cols=4096, seed=1)
            m = _with_width(spmv_model, w, indptr, indices)
            base = m.evaluate(System.BASE).cycles
            pack = m.evaluate(System.PACK).cycles
            rows.append({"bus_bits": w, "avg_nnz": nnz, "speedup": base / pack})
    return rows
