"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract
(documented in benchmarks/README.md), then structured sections for
Fig. 3a-e, Fig. 5a-c, the continuous-batching serving sweep, and (when
dry-run artifacts exist) the roofline table.

``--json PATH`` additionally writes the serving sweep as machine-readable
JSON (tokens/s, steps/s, PACK/BASE efficiency per batch size) so the perf
trajectory can be tracked run-over-run (CI uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the serving sweep as JSON to PATH")
    args = ap.parse_args()
    t0 = time.time()

    print("name,us_per_call,derived")

    # ---- kernel micro-benchmarks -------------------------------------
    from . import kernelbench
    for row in kernelbench.run():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")

    # ---- Fig 3a: speedups & bus utilizations -------------------------
    from .paper_workloads import (
        fig3a_rows, gemv_model, trmv_model, evaluate,
    )
    from repro.core import System

    n_sparse = 64 if args.quick else 192
    print("\n# Fig3a (model): workload, PACK speedup, bus util, PACK/IDEAL")
    paper = {"ismt": (5.4, 0.50), "gemv-col": (None, 0.87),
             "trmv-col": (None, 0.72), "spmv": (2.4, None),
             "prank": (None, None), "sssp": (None, 0.39)}
    for r in fig3a_rows(n=256, sparse_rows=n_sparse, avg_nnz=390):
        ps, pu = paper.get(r.name, (None, None))
        ref_s = f" (paper {ps}x)" if ps else ""
        ref_u = f" (paper {pu:.0%})" if pu else ""
        print(f"fig3a,{r.name},speedup={r.speedup_pack:.2f}x{ref_s},"
              f"util={r.util_pack:.1%}{ref_u},pack/ideal={r.pack_vs_ideal:.1%}")

    # ---- Fig 3b/c: dataflow comparisons -------------------------------
    from .fig3_scaling import (
        fig3b_gemv_dataflows, fig3c_trmv_dataflows,
        fig3d_ismt_scaling, fig3e_spmv_scaling,
    )
    print("\n# Fig3b/c: row vs col dataflow cycles")
    for name, table in (("gemv", fig3b_gemv_dataflows()),
                        ("trmv", fig3c_trmv_dataflows())):
        for flow, vals in table.items():
            print(f"fig3bc,{name}-{flow},base={vals['base']:.0f},"
                  f"pack={vals['pack']:.0f},ideal={vals['ideal']:.0f},"
                  f"util_pack={vals['util_pack']:.1%}")

    # ---- Fig 3d/e: scaling --------------------------------------------
    print("\n# Fig3d: ismt speedup vs size x width (paper peaks 1.9/3.2/5.4)")
    for row in fig3d_ismt_scaling(sizes=(8, 32, 128, 256) if args.quick else
                                  (8, 16, 32, 64, 128, 256)):
        print(f"fig3d,bus={row['bus_bits']},n={row['n']},speedup={row['speedup']:.2f}")
    print("\n# Fig3e: spmv speedup vs nnz/row x width (paper peaks 1.4/1.8/2.4)")
    for row in fig3e_spmv_scaling(n_rows=32 if args.quick else 96):
        print(f"fig3e,bus={row['bus_bits']},nnz={row['avg_nnz']},speedup={row['speedup']:.2f}")

    # ---- Fig 5: endpoint sensitivity ----------------------------------
    from .fig5_sensitivity import fig5a_indirect, fig5b_strided, fig5c_crossbar_area
    print("\n# Fig5a: indirect utilization vs (elem,index) x banks")
    pairs = ((32, 32), (32, 16), (32, 8)) if args.quick else None
    banks = (8, 16, 17, 32) if args.quick else None
    kw = {}
    if pairs:
        kw["pairs"] = pairs
    if banks:
        kw["bank_counts"] = banks
    for row in fig5a_indirect(**kw):
        print(f"fig5a,e{row['elem_bits']}i{row['index_bits']},banks={row['banks']},"
              f"util={row['utilization']:.3f},ceiling={row['ceiling_r_over_r1']:.3f}")
    print("\n# Fig5b: strided mean utilization (strides 0-63)")
    kw = {"bank_counts": banks} if banks else {}
    if args.quick:
        kw["strides"] = range(0, 16)
    for row in fig5b_strided(**kw):
        print(f"fig5b,e{row['elem_bits']},banks={row['banks']},"
              f"util={row['mean_utilization']:.3f},prime={row['prime']}")
    print("\n# Fig5c: crossbar area model")
    for row in fig5c_crossbar_area():
        print(f"fig5c,banks={row['banks']},kGE={row['area_kge']:.1f},prime={row['prime']}")

    # ---- Serving: continuous batching over paged streams --------------
    from .serving import serving_rows
    print("\n# Serving: decode tokens/s vs batch; per-step PACK vs BASE bytes")
    srows = serving_rows(quick=args.quick)
    for row in srows:
        print(f"serving,b={row['batch']},tokens_s={row['tokens_per_s']:.0f},"
              f"steps_s={row['steps_per_s']:.0f},"
              f"decode_steps={row['decode_steps']},"
              f"evictions={row['evictions']},"
              f"pack_KiB={row['pack_kib']:.0f},base_KiB={row['base_kib']:.0f},"
              f"pack_eff={row['pack_eff']:.1%},base_eff={row['base_eff']:.1%}")
    print("\n# Serving prefill: batched chunked-prefill tokens/s; "
          "PACK vs BASE efficiency of the prefill streams")
    for row in srows:
        print(f"serving_prefill,b={row['batch']},"
              f"prompt_tokens={row['prompt_tokens']},"
              f"prefill_tokens_s={row['prefill_tokens_per_s']:.0f},"
              f"prefill_steps={row['prefill_steps']},"
              f"pack_eff={row['prefill_pack_eff']:.1%},"
              f"base_eff={row['prefill_base_eff']:.1%}")

    # ---- Serving, int8 page pools: the element-size lever ---------------
    # Same prompts / workload as the fp32 sweep, but the pools hold int8
    # codes + fp32 scale sidebands: quantize-on-write, in-kernel dequant,
    # and the 8-bit packing factor in the PACK accounting.
    print("\n# Serving int8: quantized page pools (pool bytes ÷4 vs fp32; "
          "PACK packs 4x more elements per granule)")
    irows = serving_rows(quick=args.quick, kv_dtype="int8")
    fp_by_batch = {r["batch"]: r for r in srows}
    for row in irows:
        fp = fp_by_batch[row["batch"]]
        print(f"serving_int8,b={row['batch']},"
              f"tokens_s={row['tokens_per_s']:.0f},"
              f"vs_fp32={row['tokens_per_s'] / fp['tokens_per_s']:.2f}x,"
              f"pack_KiB={row['pack_kib']:.0f},"
              f"pool_bytes={row['pool_bytes']},"
              f"pool_vs_fp32={fp['pool_bytes'] / row['pool_bytes']:.2f}x,"
              f"pack_eff={row['pack_eff']:.1%},base_eff={row['base_eff']:.1%},"
              f"prefill_pack_eff={row['prefill_pack_eff']:.1%}")

    # ---- Serving, prefix sharing: refcounted pages + CoW ----------------
    # Batches repeating one page-aligned system prompt: shared pages admit
    # by refcount bump (PACK moves only remapped table indices), the
    # divergent tails prefill normally, and the row asserts bit-for-bit
    # output equality against a non-sharing scheduler.
    from .serving import shared_prefix_rows
    print("\n# Serving shared-prefix: prefill tokens saved via refcounted "
          "page sharing (outputs bit-for-bit vs non-sharing)")
    prows = shared_prefix_rows(quick=args.quick)
    for row in prows:
        print(f"serving_shared_prefix,b={row['batch']},"
              f"prompt_tokens={row['prompt_tokens']},"
              f"saved={row['prefill_tokens_saved']},"
              f"saved_frac={row['saved_frac']:.1%},"
              f"shared_pages={row['shared_pages']},"
              f"cow_copies={row['cow_copies']},"
              f"pack_eff={row['prefill_pack_eff']:.1%},"
              f"effective_pack_eff={row['effective_pack_eff']:.1%},"
              f"plain_pack_eff={row['plain_pack_eff']:.1%},"
              f"outputs_match={row['outputs_match']}")

    # ---- Serving, model families: recurrent state through the same
    # scheduler.  RWKV6/Mamba serve out of fixed-size state slots via the
    # ServableFamily protocol; the accounting dialect flips from indirect
    # page walks to strided state bursts (no index-bus term), and every row
    # asserts bit-for-bit equality with the direct sequential forward.
    from .serving import family_rows
    print("\n# Serving families: recurrent models (strided state bursts) "
          "through the shared scheduler (outputs bit-for-bit vs direct "
          "forward)")
    frows = family_rows(quick=args.quick)
    for row in frows:
        print(f"serving_families,{row['family']},b={row['batch']},"
              f"tokens_s={row['tokens_per_s']:.0f},"
              f"decode_steps={row['decode_steps']},"
              f"pack_KiB={row['pack_kib']:.0f},base_KiB={row['base_kib']:.0f},"
              f"pack_eff={row['pack_eff']:.1%},base_eff={row['base_eff']:.1%},"
              f"state_slot_bytes={row['state_slot_bytes']},"
              f"outputs_match={row['outputs_match']}")

    # ---- Serving, speculative decoding: draft-k, verify once ------------
    # The n-gram drafter proposes spec_k-1 tokens per step; one
    # paged_verify launch scores all of them in a single clamped page walk
    # and the accept/reject + KV rollback run on device.  Each row re-runs
    # the spec_k=1 workload and asserts the emitted outputs are bit-for-bit
    # the plain greedy outputs.
    from .serving import spec_rows
    print("\n# Serving speculative: multi-query verify tokens/s vs plain "
          "fused decode (outputs bit-for-bit vs spec_k=1)")
    specrows = spec_rows(quick=args.quick)
    for row in specrows:
        print(f"serving_spec,b={row['batch']},k={row['spec_k']},"
              f"tokens_s={row['tokens_per_s']:.0f},"
              f"speedup={row['speedup_vs_plain']:.2f}x,"
              f"acceptance={row['acceptance_rate']:.1%},"
              f"verify_steps={row['verify_steps']},"
              f"plain_decode_steps={row['plain_decode_steps']},"
              f"pack_eff={row['pack_eff']:.1%},base_eff={row['base_eff']:.1%},"
              f"outputs_match={row['outputs_match']}")

    # ---- Serving, degradation: throughput under pool pressure + chaos ---
    # Mixed-SLA workload vs shrinking pools and a seeded fault plan: the
    # robustness counters (evictions / preemptions / rejections / deadline
    # misses) next to tokens/s, with liveness (all_terminal) and replay
    # correctness (outputs_match) asserted per row.
    from .serving import degradation_rows
    print("\n# Serving degradation: tokens/s + SLA counters under pool "
          "pressure and injected faults")
    drows = degradation_rows(quick=args.quick)
    for row in drows:
        print(f"serving_degradation,{row['label']},"
              f"pool_pages={row['pool_pages']},"
              f"tokens_s={row['tokens_per_s']:.0f},"
              f"completed={row['completed']},"
              f"evictions={row['evictions']},"
              f"preemptions={row['preemptions']},"
              f"rejections={row['rejections']},"
              f"deadline_misses={row['deadline_misses']},"
              f"all_terminal={row['all_terminal']},"
              f"outputs_match={row['outputs_match']}")

    if args.json:
        def _json_row(r):
            return {
                "batch": r["batch"],
                "tokens": r["tokens"],
                "wall_s": r["wall_s"],
                "tokens_per_s": r["tokens_per_s"],
                "steps_per_s": r["steps_per_s"],
                "decode_steps": r["decode_steps"],
                "evictions": r["evictions"],
                "pack_efficiency": r["pack_eff"],
                "base_efficiency": r["base_eff"],
                "prompt_tokens": r["prompt_tokens"],
                "prefill_steps": r["prefill_steps"],
                "prefill_tokens_per_s": r["prefill_tokens_per_s"],
                "prefill_pack_efficiency": r["prefill_pack_eff"],
                "prefill_base_efficiency": r["prefill_base_eff"],
                "kv_elem_bits": r["kv_elem_bits"],
                "pool_bytes": r["pool_bytes"],
            }

        payload = {
            "benchmark": "serving",
            "quick": bool(args.quick),
            "rows": [_json_row(r) for r in srows],
            "serving_int8": {
                "rows": [dict(
                    _json_row(r),
                    tokens_per_s_vs_fp32=(
                        r["tokens_per_s"]
                        / fp_by_batch[r["batch"]]["tokens_per_s"]
                    ),
                    pool_bytes_vs_fp32=(
                        fp_by_batch[r["batch"]]["pool_bytes"]
                        / r["pool_bytes"]
                    ),
                ) for r in irows],
            },
            "serving_shared_prefix": {"rows": prows},
            "serving_families": {"rows": frows},
            "serving_spec": {"rows": specrows},
            "serving_degradation": {"rows": drows},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# serving sweep written to {args.json}")

        # One dated line per run so the perf trajectory is greppable
        # without diffing full artifacts.  Lives next to the JSON path;
        # the committed full-sweep history is BENCH_history.jsonl at the
        # repo root, quick CI runs append to their own workspace copy.
        hist = os.path.join(
            os.path.dirname(os.path.abspath(args.json)) or ".",
            "BENCH_history.jsonl")
        spec_best = max(specrows, key=lambda r: r["speedup_vs_plain"])
        entry = {
            "date": datetime.date.today().isoformat(),
            "quick": bool(args.quick),
            "decode_tokens_per_s": {
                str(r["batch"]): round(r["tokens_per_s"], 1) for r in srows},
            "spec_best": {
                "batch": spec_best["batch"],
                "spec_k": spec_best["spec_k"],
                "speedup_vs_plain": round(spec_best["speedup_vs_plain"], 3),
                "acceptance_rate": round(spec_best["acceptance_rate"], 3),
            },
            "spec_outputs_match": all(r["outputs_match"] for r in specrows),
        }
        with open(hist, "a") as f:
            f.write(json.dumps(entry) + "\n")
        print(f"# history entry appended to {hist}")

    # ---- Roofline (if dry-run artifacts exist) ------------------------
    try:
        from .roofline import roofline_rows, print_table
        rows = roofline_rows("pod16x16")
        if rows:
            print("\n# Roofline (single-pod dry-run artifacts)")
            print_table(rows)
    except Exception as e:  # noqa: BLE001
        print(f"\n# Roofline skipped: {e}")

    print(f"\n# total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
